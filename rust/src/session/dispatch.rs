//! Sharded multi-process matrix executor.
//!
//! `execute_sharded` promotes the in-process ready-queue scheduler to
//! a fleet of `mlonmcu worker` child processes. The parent plans the
//! same deduplicated stage DAG as the serial scheduler
//! (`scheduler::plan`), publishes each Load/Tune/Build task as a file
//! in a session-local work queue, and spawns N workers that claim
//! tasks, execute them, and exchange every artifact exclusively
//! through the verified environment store (`store.rs` /
//! `persist.rs`). The per-run tails (Compile → Run → Postprocess)
//! then replay in the parent through the ordinary scheduler with a
//! *worker overlay*, which charges each worker's host seconds and
//! execution attribution to the same run a serial pass would have
//! charged — serial and sharded runs of one matrix therefore produce
//! byte-identical reports (proven by `tests/dispatch_equivalence.rs`).
//!
//! ## Queue layout (under `<session>/queue/<n>/`)
//!
//! ```text
//! task-<id>.json        one Load/Tune/Build task (spec slice, key,
//!                       dep ids; "format" = persist::FORMAT_VERSION)
//! task-<id>.lease       claim marker: "<pid>-<nonce>", heartbeat by
//!                       rewriting; create_new is the claim
//! task-<id>.done.json   outcome: status, executed, store lookup,
//!                       host seconds (written tmp-then-rename)
//! ```
//!
//! ## Fault tolerance
//!
//! * A worker killed mid-task leaves a lease whose pid is dead: any
//!   live worker (and the parent) reclaims it immediately via
//!   `util::proc::pid_alive`, or after the heartbeat timeout
//!   (`dispatch.lease_ms`) on platforms without /proc.
//! * The parent itself drains the queue alongside the workers, so the
//!   matrix completes even if every child dies.
//! * Reclaim races can at worst execute a task twice: artifacts are
//!   content-addressed and done-markers rename atomically, so
//!   duplicates are idempotent.
//! * A task whose store artifact vanishes before the tail pass is
//!   recomputed locally by the scheduler's overlay fallthrough.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::Environment;
use crate::data::Json;
use crate::features::Features;
use crate::session::cache::{
    Artifact, ArtifactCache, CachedStage, StageKey, TuneOutcome, TuneParams,
};
use crate::session::persist;
use crate::session::run::{self, RunRecord, RunSpec};
use crate::session::scheduler::{
    self, Overlay, RunOptions, StageExecCounts, StageKind, TaskGraph,
    WorkerOutcome,
};
use crate::session::store::{write_atomic, EnvStore, StoreLookup};
use crate::session::transport::{Claim, Client, RemoteConfig, RemoteStore};
use crate::session::Session;
use crate::util::proc::stale_owner_file;
use crate::util::Stopwatch;

/// Counters of one sharded invocation, reconstructed from the worker
/// outcomes so `SessionTiming` and the report note carry exactly the
/// numbers an equivalent serial pass would have produced.
#[derive(Debug, Clone, Copy, Default)]
pub struct DispatchCounters {
    pub hits: usize,
    pub misses: usize,
    pub disk_hits: usize,
    pub disk_misses: usize,
    pub verify_fails: usize,
    pub execs: StageExecCounts,
    /// Worker child processes that actually spawned.
    pub workers_spawned: usize,
    /// Faults the worker processes injected (reported per done
    /// record); the parent's own injections are counted separately
    /// from its process-global registry.
    pub faults: u64,
}

/// Store-lookup outcome a worker observed for its own task key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lookup {
    Hit,
    Miss,
    Corrupt,
    /// Task never consulted the store (upstream failure propagated).
    None,
}

impl Lookup {
    fn name(self) -> &'static str {
        match self {
            Lookup::Hit => "hit",
            Lookup::Miss => "miss",
            Lookup::Corrupt => "corrupt",
            Lookup::None => "none",
        }
    }

    fn from_name(s: &str) -> Lookup {
        match s {
            "hit" => Lookup::Hit,
            "miss" => Lookup::Miss,
            "corrupt" => Lookup::Corrupt,
            _ => Lookup::None,
        }
    }
}

/// One published stage task, as read back from the queue.
struct QueueTask {
    id: usize,
    kind: CachedStage,
    key: StageKey,
    spec: RunSpec,
    /// Fingerprint of the model file contents; remote workers (whose
    /// homes may not hold the model) fetch the bytes from the server's
    /// blob pool under this key. 0 = unknown, fall back to local files.
    model_fp: u64,
    /// (task id, kind, key) of each dependency, id-ascending — the
    /// order the serial scheduler picks failures in.
    deps: Vec<(usize, CachedStage, StageKey)>,
}

/// Outcome record of one task (the `.done.json` payload).
#[derive(Clone)]
struct DoneRecord {
    ok: bool,
    /// Failing stage name ("load"/"tune"/"build"), possibly upstream.
    stage: String,
    error: String,
    executed: bool,
    lookup: Lookup,
    secs: f64,
    /// Faults the executing worker process injected during this task
    /// (0 from parents — they report through their own registry).
    faults: u64,
}

impl DoneRecord {
    fn ok(executed: bool, lookup: Lookup, secs: f64) -> DoneRecord {
        DoneRecord {
            ok: true,
            stage: String::new(),
            error: String::new(),
            executed,
            lookup,
            secs,
            faults: 0,
        }
    }

    fn failed(stage: &str, error: String, lookup: Lookup, secs: f64) -> DoneRecord {
        DoneRecord {
            ok: false,
            stage: stage.to_string(),
            error,
            executed: false,
            lookup,
            secs,
            faults: 0,
        }
    }

    fn to_json(&self, id: usize) -> Json {
        Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("status", Json::Str(if self.ok { "ok" } else { "failed" }.into())),
            ("stage", Json::Str(self.stage.clone())),
            ("error", Json::Str(self.error.clone())),
            ("executed", Json::Bool(self.executed)),
            ("lookup", Json::Str(self.lookup.name().into())),
            ("secs", Json::Num(self.secs)),
            ("faults", Json::Num(self.faults as f64)),
        ])
    }

    fn from_json(j: &Json) -> Option<DoneRecord> {
        Some(DoneRecord {
            ok: j.get("status")?.as_str()? == "ok",
            stage: j.get("stage")?.as_str()?.to_string(),
            error: j.get("error")?.as_str()?.to_string(),
            executed: matches!(j.get("executed"), Some(Json::Bool(true))),
            lookup: Lookup::from_name(j.get("lookup")?.as_str()?),
            secs: j.get("secs")?.as_f64()?,
            // absent in records from older writers: no faults
            faults: j
                .get("faults")
                .and_then(Json::as_i64)
                .unwrap_or(0)
                .max(0) as u64,
        })
    }
}

/// Everything a drain loop (worker process or assisting parent)
/// needs to claim and execute queue tasks.
struct WorkerCtx<'a> {
    queue: &'a Path,
    env: &'a Environment,
    store: Arc<EnvStore>,
    tune: TuneParams,
    lease_ms: u64,
    tasks: Vec<QueueTask>,
}

// ------------------------------------------------------------ parent --

/// Execute `specs` by sharding Load/Tune/Build across worker
/// processes, then replay the per-run tails in-process. Returns the
/// records (spec order) and serial-equivalent counters.
pub fn execute_sharded(
    session: &Session,
    specs: &[RunSpec],
    cache: &ArtifactCache,
    opts: RunOptions,
) -> Result<(Vec<RunRecord>, DispatchCounters)> {
    let env = session.env();
    let store = cache
        .env_store()
        .cloned()
        .context("sharded dispatch requires the environment store")?;
    let tune = scheduler::tune_params(env);
    let (model_fp, model_bytes) = scheduler::model_fingerprints(session, specs);
    let graph = scheduler::plan(specs, tune, &model_fp, true);
    let qtasks = queue_tasks_from_graph(&graph, specs, &model_fp);

    let queue = next_queue_dir(&session.dir)?;
    publish(&queue, &qtasks)?;

    let n_stage = graph.stage_task_count();
    let workers = opts.workers.min(n_stage.max(1));
    crate::log_info!(
        "session {}: dispatching {} stage task(s) to {} worker process(es) \
         (queue {})",
        session.id,
        n_stage,
        workers,
        queue.display()
    );
    let mut children = Reaper(spawn_workers(env, &queue, workers));
    let spawned = children.0.len();
    // (fleet is killed + reaped on drop, even on early error returns)
    if spawned < workers {
        crate::log_warn!(
            "dispatch: only {spawned} of {workers} worker(s) spawned; \
             the parent drains the rest in-process"
        );
    }

    // supervise the fleet: reap exited children (so their pids read
    // as dead), break stale leases so live workers take over a killed
    // worker's task, and drain the queue in-process once no children
    // remain — the matrix completes even if every worker dies
    let ctx = WorkerCtx {
        queue: &queue,
        env,
        store,
        tune,
        lease_ms: env.dispatch_lease_ms(),
        // the parent already holds the graph: no need to round-trip
        // its own queue files (workers parse them via read_queue_tasks)
        tasks: qtasks,
    };
    supervise(&ctx, &mut children)?;
    drop(children); // all tasks done: reap (and stop) the fleet

    // worker outcomes -> overlay + serial-equivalent counters
    let (overlay, mut counters) =
        reconstruct_outcomes(&graph, cache, |id| read_done(&queue, id))?;
    counters.workers_spawned = spawned;

    // deterministic tail pass: the same scheduler over the *same*
    // planned graph (no re-read/re-hash of the models), stages served
    // from the cache tiers with worker attribution
    let (records, local_execs) = scheduler::execute_planned(
        session,
        specs,
        cache,
        opts,
        &graph,
        &model_bytes,
        tune,
        Some(&overlay),
    )?;
    // stages the store lost between worker write and tail pass were
    // recomputed locally: count those executions too
    counters.execs.loads += local_execs.loads;
    counters.execs.tunes += local_execs.tunes;
    counters.execs.builds += local_execs.builds;
    Ok((records, counters))
}

/// Fold per-task outcome records (file-queue done markers or served
/// done docs) back into the scheduler overlay plus the exact counters
/// an equivalent serial pass would have produced. Shared by the local
/// sharded path and the remote-fleet path so both reconstruct
/// byte-identical report notes.
fn reconstruct_outcomes(
    graph: &TaskGraph,
    cache: &ArtifactCache,
    mut get_done: impl FnMut(usize) -> Option<DoneRecord>,
) -> Result<(Overlay, DispatchCounters)> {
    let mut overlay = Overlay::new();
    let mut counters = DispatchCounters::default();
    for (id, task) in graph.tasks.iter().enumerate() {
        if task.kind == StageKind::Tail {
            continue;
        }
        let done = get_done(id)
            .with_context(|| format!("queue task {id} finished without an outcome"))?;
        let key = task.key.expect("stage tasks are keyed");
        let shared = task.consumers.len() - 1;
        if done.ok {
            if done.executed {
                counters.misses += 1;
                match done.lookup {
                    Lookup::Miss => counters.disk_misses += 1,
                    Lookup::Corrupt => counters.verify_fails += 1,
                    _ => {}
                }
                match task.kind {
                    StageKind::Load => counters.execs.loads += 1,
                    StageKind::Tune => counters.execs.tunes += 1,
                    StageKind::Build => counters.execs.builds += 1,
                    StageKind::Tail => {}
                }
            } else {
                counters.hits += 1;
                // a serial pass serves what this session already holds
                // in memory from the memory tier, not the store — only
                // count a disk hit when memory could not have served it
                if !cache.contains_mem(key) {
                    counters.disk_hits += 1;
                }
            }
            counters.hits += shared;
        } else {
            match done.lookup {
                Lookup::Miss => {
                    counters.misses += 1;
                    counters.disk_misses += 1;
                }
                Lookup::Corrupt => {
                    counters.misses += 1;
                    counters.verify_fails += 1;
                }
                // propagated upstream failures never consulted the
                // store and count nothing, exactly like the serial
                // scheduler's early return
                _ => {}
            }
        }
        counters.faults += done.faults;
        overlay.insert(
            key.0,
            WorkerOutcome {
                executed: done.executed,
                secs: done.secs,
                failed: (!done.ok)
                    .then(|| (intern_stage(&done.stage, task.kind), done.error)),
            },
        );
    }
    Ok((overlay, counters))
}

/// Map a worker-reported stage name back to the interned form used by
/// `RunStatus`; unknown names fall back to the task's own kind.
fn intern_stage(name: &str, kind: StageKind) -> &'static str {
    match name {
        "load" => "load",
        "tune" => "tune",
        "build" => "build",
        _ => kind.stage_name(),
    }
}

// ----------------------------------------------------- remote fleet --

/// Everything a remote drain step needs: the wire client plus the
/// local environment (store, model dirs) behind it.
struct RemoteCtx<'a> {
    client: &'a Client,
    env: &'a Environment,
    store: Arc<EnvStore>,
    /// Ship drained trace spans back over the wire after each traced
    /// task. True only in `mlonmcu worker --connect` processes — the
    /// dispatching parent keeps its own spans in the local tracer.
    ship_spans: bool,
}

/// Outcome of one remote claim attempt.
enum Step {
    /// Claimed, executed, and published a task.
    Worked,
    /// Nothing claimable right now.
    Idle,
    /// The server refused the claim (artifact-format mismatch).
    Refused,
}

/// Execute `specs` against a serve daemon: push the planned stage DAG
/// into the served task queue, let `mlonmcu worker --connect` fleets
/// (plus this parent, when the queue stalls) drain it, then replay the
/// tails in-process exactly like `execute_sharded`. Returns `Ok(None)`
/// when the server cannot be used — the caller falls back to local
/// execution; remote trouble is never fatal to the matrix.
pub fn execute_remote(
    session: &Session,
    specs: &[RunSpec],
    cache: &ArtifactCache,
    opts: RunOptions,
    remote: &Arc<RemoteStore>,
) -> Result<Option<(Vec<RunRecord>, DispatchCounters)>> {
    let env = session.env();
    let store = cache
        .env_store()
        .cloned()
        .context("remote dispatch requires the environment store")?;
    let client = remote.client();
    match client.ping() {
        Ok(v) if v == persist::FORMAT_VERSION => {}
        Ok(v) => {
            crate::log_warn!(
                "remote dispatch: server {} speaks artifact format {v}, \
                 this build speaks {}; executing in-process",
                client.addr(),
                persist::FORMAT_VERSION
            );
            return Ok(None);
        }
        Err(e) => {
            crate::log_warn!(
                "remote dispatch: server {} unreachable ({e:#}); \
                 executing in-process",
                client.addr()
            );
            return Ok(None);
        }
    }

    let tune = scheduler::tune_params(env);
    let (model_fp, model_bytes) = scheduler::model_fingerprints(session, specs);
    let graph = scheduler::plan(specs, tune, &model_fp, true);
    let qtasks = queue_tasks_from_graph(&graph, specs, &model_fp);

    // ship the model bytes: a remote worker's home need not hold them
    for (name, bytes) in &model_bytes {
        let fp = model_fp.get(name).copied().unwrap_or(0);
        if fp == 0 {
            continue;
        }
        if let Err(e) = client.blob_put(fp, bytes.as_slice()) {
            crate::log_warn!(
                "remote dispatch: publishing model {name} failed ({e:#}); \
                 executing in-process"
            );
            return Ok(None);
        }
    }

    let lease_ms = env.dispatch_lease_ms();
    // the active fault plan rides the queue doc (like the trace flag)
    // so every remote worker arms the same deterministic plan; the
    // canonical spec keeps per-rule seeds stable across the fleet
    let fault_spec = crate::util::faults::spec_string()
        .or_else(|| env.fault_spec())
        .unwrap_or_default();
    let queue_doc = Json::obj(vec![
        ("format", Json::Num(persist::FORMAT_VERSION as f64)),
        ("lease_ms", Json::Num(lease_ms as f64)),
        // traced queues tell every remote worker to record spans and
        // ship them back (drained by this parent's poll loop); metric
        // snapshots ride the same two paths
        ("trace", Json::Bool(crate::util::trace::enabled())),
        ("metrics", Json::Bool(crate::util::metrics::enabled())),
        ("faults", Json::Str(fault_spec)),
        ("deadline_ms", Json::Num(env.retry_deadline_ms() as f64)),
        (
            "tune",
            Json::obj(vec![
                ("trials", Json::Num(tune.trials as f64)),
                ("seed", Json::Num(tune.seed as f64)),
            ]),
        ),
        ("tasks", Json::Arr(qtasks.iter().map(task_doc).collect())),
    ]);
    let qid = match client.qpush(&queue_doc) {
        Ok(q) => q,
        Err(e) => {
            crate::log_warn!(
                "remote dispatch: queue push failed ({e:#}); \
                 executing in-process"
            );
            return Ok(None);
        }
    };
    let n_stage = graph.stage_task_count();
    crate::log_info!(
        "session {}: dispatching {} stage task(s) to remote queue {} at {}",
        session.id,
        n_stage,
        qid,
        client.addr()
    );

    // poll until every task settled; drain one task in-process whenever
    // no worker is connected or the queue stopped progressing for a
    // grace period — the matrix completes even with zero workers
    let ctx = RemoteCtx { client, env, store, ship_spans: false };
    let grace_ms = remote.config().grace_ms;
    let mut done: HashMap<usize, DoneRecord> = HashMap::new();
    let mut fleet_max = 0usize;
    loop {
        let poll = match client.poll(qid) {
            Ok(p) => p,
            Err(e) => {
                crate::log_warn!(
                    "remote dispatch: server lost mid-run ({e:#}); \
                     executing in-process"
                );
                return Ok(None);
            }
        };
        for rec in poll.get("done").and_then(Json::as_arr).unwrap_or(&[]) {
            let Some(id) = rec.get("id").and_then(Json::as_i64) else {
                continue;
            };
            if let Some(r) = DoneRecord::from_json(rec) {
                done.insert(id.max(0) as usize, r);
            }
        }
        // remote workers' spans ride the poll responses; merge them
        // into this parent's tracer (no-op while tracing is off)
        if let Some(events) = poll.get("spans").and_then(Json::as_arr) {
            crate::util::trace::record_all(
                events
                    .iter()
                    .filter_map(|e| crate::util::trace::span_from_event(e).ok())
                    .collect(),
            );
        }
        // remote workers' metric snapshots ride the same responses;
        // merge into this parent's registry (no-op while metrics off)
        for doc in poll.get("metrics").and_then(Json::as_arr).unwrap_or(&[]) {
            if let Ok(snap) = crate::util::metrics::Snapshot::from_json(doc) {
                crate::util::metrics::record_all(&snap);
            }
        }
        let as_count = |k: &str| {
            poll.get(k).and_then(Json::as_i64).unwrap_or(0).max(0) as usize
        };
        let total = as_count("total");
        let workers = as_count("workers");
        fleet_max = fleet_max.max(workers);
        if done.len() >= total {
            break;
        }
        if workers == 0 || as_count("stalled_ms") as u64 > grace_ms {
            match remote_step(&ctx, qid) {
                Ok(Step::Worked) => {}
                Ok(Step::Idle) => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Ok(Step::Refused) => {
                    crate::log_warn!(
                        "remote dispatch: server refused the parent's own \
                         claim; executing in-process"
                    );
                    return Ok(None);
                }
                Err(e) => {
                    crate::log_warn!(
                        "remote dispatch: server lost mid-drain ({e:#}); \
                         executing in-process"
                    );
                    return Ok(None);
                }
            }
        } else {
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    // served outcomes -> overlay + serial-equivalent counters, then
    // the identical deterministic tail pass as the local sharded path
    let (overlay, mut counters) =
        reconstruct_outcomes(&graph, cache, |id| done.get(&id).cloned())?;
    counters.workers_spawned = fleet_max;
    let (records, local_execs) = scheduler::execute_planned(
        session,
        specs,
        cache,
        opts,
        &graph,
        &model_bytes,
        tune,
        Some(&overlay),
    )?;
    counters.execs.loads += local_execs.loads;
    counters.execs.tunes += local_execs.tunes;
    counters.execs.builds += local_execs.builds;
    Ok(Some((records, counters)))
}

/// Entry point of `mlonmcu worker --connect`: claim Load/Tune/Build
/// tasks from the serve daemon at `addr` until the server goes away.
/// A vanished server ends the shift cleanly (exit 0) — workers are
/// cattle, the dispatching parent owns completion.
pub fn worker_main_remote(addr: &str, env: &Environment) -> Result<i32> {
    crate::util::faults::set_worker_role();
    let store = Arc::new(EnvStore::open_with(
        &env.cache_dir(),
        env.cache_budget_bytes(),
        env.store_lock_stale_ms(),
    )?);
    let client = Client::new(RemoteConfig {
        addr: addr.to_string(),
        timeout_ms: env.remote_timeout_ms(),
        retries: env.remote_retries(),
        backoff_ms: env.remote_backoff_ms(),
        grace_ms: env.remote_grace_ms(),
    });
    let ctx = RemoteCtx { client: &client, env, store, ship_spans: true };
    crate::log_info!(
        "worker: draining queues of {} (home {})",
        client.addr(),
        env.root.display()
    );
    loop {
        match remote_step(&ctx, 0) {
            Ok(Step::Worked) => {}
            Ok(Step::Idle) => std::thread::sleep(Duration::from_millis(40)),
            Ok(Step::Refused) => {
                crate::log_warn!(
                    "worker: server {} refused the claim (artifact-format \
                     mismatch?); exiting",
                    client.addr()
                );
                return Ok(0);
            }
            Err(e) => {
                crate::log_info!(
                    "worker: server {} gone ({e:#}); exiting",
                    client.addr()
                );
                return Ok(0);
            }
        }
    }
}

/// Claim and execute at most one task from the served queue (`queue`
/// picks one, 0 = any). Transport failures bubble up; the caller
/// decides whether that ends a worker's shift or degrades the parent
/// to in-process execution.
fn remote_step(ctx: &RemoteCtx, queue: u64) -> Result<Step> {
    // batched claim: the artifacts this task will fetch (its own
    // entry, its deps') ride the claim response — each one present in
    // the map saves a GET round trip during execution
    let (claim, entries) = ctx.client.claim_deps(queue)?;
    let doc = match claim {
        Claim::Task(doc) => doc,
        Claim::Empty => return Ok(Step::Idle),
        Claim::Refused => return Ok(Step::Refused),
    };
    let prefetched: HashMap<(CachedStage, StageKey), Vec<u8>> =
        entries.into_iter().collect();
    let qid =
        doc.get("queue").and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
    // a traced queue turns this worker's tracer on for the rest of the
    // shift; spans drain back to the dispatching parent per task
    let traced = matches!(doc.get("trace"), Some(Json::Bool(true)));
    if traced && ctx.ship_spans {
        crate::util::trace::enable();
    }
    // a metered queue does the same for the metrics registry; snapshots
    // drain back to the dispatching parent per task
    let metered = matches!(doc.get("metrics"), Some(Json::Bool(true)));
    if metered && ctx.ship_spans {
        crate::util::metrics::enable();
    }
    // a fault-planned queue arms the same deterministic plan in this
    // worker. Only workers install from the claim — the dispatching
    // parent already armed its own registry — and re-installing an
    // identical spec is skipped so rule counters survive across claims
    if ctx.ship_spans {
        if let Some(spec) = doc
            .get("faults")
            .and_then(Json::as_str)
            .filter(|s| !s.is_empty())
        {
            if crate::util::faults::spec_string().as_deref() != Some(spec) {
                if let Err(e) = crate::util::faults::install(spec) {
                    crate::log_warn!(
                        "worker: fault plan in claim rejected ({e})"
                    );
                }
            }
        }
    }
    let lease_ms = doc
        .get("lease_ms")
        .and_then(Json::as_i64)
        .unwrap_or(5000)
        .clamp(50, 600_000) as u64;
    // tune params travel with the claim: a worker reproduces the
    // dispatching parent's schedules, never its own environment's
    let tune = TuneParams {
        trials: doc
            .get("tune")
            .and_then(|t| t.get("trials"))
            .and_then(Json::as_i64)
            .unwrap_or(600)
            .max(1) as usize,
        seed: doc
            .get("tune")
            .and_then(|t| t.get("seed"))
            .and_then(Json::as_i64)
            .unwrap_or(7)
            .max(0) as u64,
    };
    let tdoc = doc.get("task").context("claim without a task")?;
    let tid = tdoc
        .get("id")
        .and_then(Json::as_i64)
        .context("claimed task without an id")?
        .max(0) as usize;
    let task = parse_task(tid, tdoc)?;
    let mut deps_done: HashMap<usize, DoneRecord> = HashMap::new();
    for rec in doc.get("deps_done").and_then(Json::as_arr).unwrap_or(&[]) {
        let Some(id) = rec.get("id").and_then(Json::as_i64) else {
            continue;
        };
        if let Some(r) = DoneRecord::from_json(rec) {
            deps_done.insert(id.max(0) as usize, r);
        }
    }

    // heartbeat the claim while executing, exactly like the local
    // lease's touch thread — a silent claimant's task is reclaimed by
    // the server after lease_ms
    let stop = AtomicBool::new(false);
    let done = std::thread::scope(|scope| {
        scope.spawn(|| {
            let beat = Duration::from_millis((lease_ms / 4).clamp(10, 250));
            loop {
                let mut slept = Duration::ZERO;
                while slept < beat {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    let step = Duration::from_millis(20).min(beat - slept);
                    std::thread::sleep(step);
                    slept += step;
                }
                // an injected stall sleeps here, so the served lease
                // ages out and the server re-opens the task
                crate::util::faults::fire("queue.lease.heartbeat");
                if stop.load(Ordering::Relaxed)
                    || ctx.client.beat(qid, tid as u64).is_err()
                {
                    return; // finished, or server gone (DONE reports it)
                }
            }
        });
        let done = run_remote_task(ctx, &task, &deps_done, tune, &prefetched);
        stop.store(true, Ordering::Relaxed);
        done
        // scope exit joins the heartbeat (wakes within one 20ms slice)
    });
    // ship spans BEFORE the done op: both ride this one connection, so
    // the parent poll that observes the completion also drains them
    if traced && ctx.ship_spans {
        let spans = crate::util::trace::drain();
        if !spans.is_empty() {
            if let Err(e) = ctx.client.trace_put(qid, spans) {
                crate::log_warn!("worker: trace spans not shipped ({e:#})");
            }
        }
    }
    // metric snapshots follow the same ship-before-done discipline
    if metered && ctx.ship_spans {
        let snap = crate::util::metrics::drain();
        if !snap.is_empty() {
            if let Err(e) = ctx.client.metrics_put(qid, &snap) {
                crate::log_warn!("worker: metrics not shipped ({e:#})");
            }
        }
    }
    ctx.client.done(qid, tid as u64, &done.to_json(tid))?;
    Ok(Step::Worked)
}

/// Execute one claimed remote task; mirrors `run_stage_task` with the
/// server as the primary artifact tier and the local store behind it.
fn run_remote_task(
    ctx: &RemoteCtx,
    t: &QueueTask,
    deps_done: &HashMap<usize, DoneRecord>,
    tune: TuneParams,
    prefetched: &HashMap<(CachedStage, StageKey), Vec<u8>>,
) -> DoneRecord {
    // propagate upstream failures without executing — deps are
    // id-ordered, matching the serial scheduler's earliest-dep pick
    for &(d, _, _) in &t.deps {
        if let Some(dep) = deps_done.get(&d) {
            if !dep.ok {
                return DoneRecord::failed(
                    &dep.stage,
                    dep.error.clone(),
                    Lookup::None,
                    0.0,
                );
            }
        }
    }
    let mut span = crate::util::trace::span("stage", t.kind.name())
        .arg_with("task", || t.id.to_string())
        .arg_with("backend", || t.spec.backend.clone())
        .arg_with("schedule", || {
            t.spec.schedule.clone().unwrap_or_else(|| "default".into())
        });
    let faults_before = crate::util::faults::injected_count();
    let lookup = remote_primary_lookup(ctx, t, prefetched);
    if lookup == Lookup::Hit {
        span.note("outcome", "hit");
        let mut done = DoneRecord::ok(false, Lookup::Hit, 0.0);
        done.faults = task_faults(faults_before);
        return done;
    }
    let watch = Stopwatch::start();
    // bounded retry with backoff; panics are caught per attempt and the
    // exhausted error carries the quarantine [attempts=N] marker
    let result = scheduler::with_retry(
        ctx.env.retry_attempts(),
        ctx.env.retry_backoff_ms(),
        t.kind.name(),
        || execute_remote_stage(ctx, t, tune, prefetched),
    );
    let secs = watch.elapsed_s();
    crate::util::metrics::observe(
        crate::util::metrics::stage_metric(t.kind.name()),
        (secs * 1e6) as u64,
    );
    let mut done = match result {
        Ok(artifact) => {
            // server first — it is the fleet's exchange medium and the
            // parent's tail pass fetches through it
            let bytes = persist::encode(t.key, &artifact);
            if let Err(e) = ctx.client.put(t.kind, t.key, &bytes) {
                crate::log_warn!(
                    "worker: artifact {} not pushed: {e:#}",
                    t.key.hex()
                );
            }
            if let Err(e) = ctx.store.save(t.key, &artifact) {
                crate::log_warn!(
                    "worker: artifact {} not saved locally: {e}",
                    t.key.hex()
                );
            }
            DoneRecord::ok(true, lookup, secs)
        }
        Err(e) => {
            DoneRecord::failed(t.kind.name(), e.to_string(), lookup, secs)
        }
    };
    done.faults = task_faults(faults_before);
    span.note("outcome", if done.ok { "ok" } else { "failed" });
    done
}

/// Faults this process injected since `before` — but only reported
/// from worker processes; a draining parent's injections are already
/// counted by its own session-global delta and must not be doubled.
fn task_faults(before: u64) -> u64 {
    if crate::util::faults::worker_role() {
        crate::util::faults::injected_count().saturating_sub(before)
    } else {
        0
    }
}

/// Primary lookup for a claimed task: the server (shared across the
/// fleet) first, the local store second. Hits replicate toward the
/// other tier — a server hit lands in the local store, a local hit is
/// pushed back up so the parent's tail pass and the rest of the fleet
/// can fetch it remotely.
fn remote_primary_lookup(
    ctx: &RemoteCtx,
    t: &QueueTask,
    prefetched: &HashMap<(CachedStage, StageKey), Vec<u8>>,
) -> Lookup {
    // an entry that rode the claim is the server tier answering early
    // — same verify, same replication, zero extra round trips
    if let Some(bytes) = prefetched.get(&(t.kind, t.key)) {
        if persist::decode(bytes, t.key).is_ok() {
            let _ = ctx.store.save_raw(t.key, t.kind, bytes);
            return Lookup::Hit;
        }
        // corrupt prefetch: fall through to the usual tiers
    }
    if let Ok(Some(bytes)) = ctx.client.get(t.kind, t.key) {
        if persist::decode(&bytes, t.key).is_ok() {
            let _ = ctx.store.save_raw(t.key, t.kind, &bytes);
            return Lookup::Hit;
        }
        // a corrupt served entry is only a miss; fall through
    }
    match ctx.store.load(t.key, t.kind) {
        StoreLookup::Hit(_) => {
            if let Some(bytes) = ctx.store.load_raw(t.key, t.kind) {
                if let Err(e) = ctx.client.put(t.kind, t.key, &bytes) {
                    crate::log_warn!(
                        "worker: artifact {} not pushed: {e:#}",
                        t.key.hex()
                    );
                }
            }
            Lookup::Hit
        }
        StoreLookup::Miss => Lookup::Miss,
        StoreLookup::Corrupt => Lookup::Corrupt,
    }
}

fn execute_remote_stage(
    ctx: &RemoteCtx,
    t: &QueueTask,
    tune: TuneParams,
    prefetched: &HashMap<(CachedStage, StageKey), Vec<u8>>,
) -> Result<Artifact> {
    match t.kind {
        CachedStage::Load => load_graph_remote(ctx, t).map(Artifact::Graph),
        CachedStage::Tune => {
            let graph = fetch_graph_remote(ctx, t, prefetched)?;
            run::stage_tune(&t.spec, &graph, tune).map(Artifact::Tune)
        }
        CachedStage::Build => {
            let graph = fetch_graph_remote(ctx, t, prefetched)?;
            let tuned = fetch_tune_remote(ctx, t, &graph, tune, prefetched)?;
            run::stage_build(&t.spec, &graph, tuned.map(|o| o.schedule))
                .map(|b| Artifact::Build(Arc::new(b)))
        }
    }
}

/// The model graph: server blob pool first (the dispatching parent
/// ships every model's bytes), local model dirs as fallback.
fn load_graph_remote(
    ctx: &RemoteCtx,
    t: &QueueTask,
) -> Result<Arc<crate::graph::Graph>> {
    if t.model_fp != 0 {
        if let Ok(Some(bytes)) = ctx.client.blob_get(t.model_fp) {
            return crate::frontends::load_model_from_bytes(
                &bytes,
                &t.spec.model,
            )
            .map(Arc::new);
        }
    }
    run::stage_load(ctx.env, &t.spec).map(Arc::new)
}

/// A dependency artifact: server first, local store second. `None`
/// means recompute (both tiers lost it — budget eviction).
fn fetch_dep_remote(
    ctx: &RemoteCtx,
    key: StageKey,
    stage: CachedStage,
    prefetched: &HashMap<(CachedStage, StageKey), Vec<u8>>,
) -> Option<Artifact> {
    // entries that rode the claim response skip the GET round trip;
    // they go through the same decode-verify as wire-fetched bytes
    if let Some(bytes) = prefetched.get(&(stage, key)) {
        if let Ok(a) = persist::decode(bytes, key) {
            if a.stage() == stage {
                let _ = ctx.store.save_raw(key, stage, bytes);
                return Some(a);
            }
        }
    }
    if let Ok(Some(bytes)) = ctx.client.get(stage, key) {
        if let Ok(a) = persist::decode(&bytes, key) {
            if a.stage() == stage {
                let _ = ctx.store.save_raw(key, stage, &bytes);
                return Some(a);
            }
        }
    }
    match ctx.store.load(key, stage) {
        StoreLookup::Hit(a) => Some(a),
        _ => None,
    }
}

fn fetch_graph_remote(
    ctx: &RemoteCtx,
    t: &QueueTask,
    prefetched: &HashMap<(CachedStage, StageKey), Vec<u8>>,
) -> Result<Arc<crate::graph::Graph>> {
    for &(_, kind, key) in &t.deps {
        if kind == CachedStage::Load {
            if let Some(Artifact::Graph(g)) =
                fetch_dep_remote(ctx, key, CachedStage::Load, prefetched)
            {
                return Ok(g);
            }
        }
    }
    load_graph_remote(ctx, t)
}

fn fetch_tune_remote(
    ctx: &RemoteCtx,
    t: &QueueTask,
    graph: &crate::graph::Graph,
    tune: TuneParams,
    prefetched: &HashMap<(CachedStage, StageKey), Vec<u8>>,
) -> Result<Option<TuneOutcome>> {
    let Some(&(_, _, key)) =
        t.deps.iter().find(|&&(_, k, _)| k == CachedStage::Tune)
    else {
        return Ok(None);
    };
    if let Some(Artifact::Tune(o)) =
        fetch_dep_remote(ctx, key, CachedStage::Tune, prefetched)
    {
        return Ok(Some(o));
    }
    run::stage_tune(&t.spec, graph, tune).map(Some)
}

/// First free `<session>/queue/<n>` (repeated `run_matrix` calls on
/// one session each get a fresh queue).
fn next_queue_dir(session_dir: &Path) -> Result<PathBuf> {
    let root = session_dir.join("queue");
    fs::create_dir_all(&root)?;
    let mut n = 0usize;
    loop {
        let dir = root.join(format!("{n}"));
        if !dir.exists() {
            fs::create_dir_all(&dir)?;
            return Ok(dir);
        }
        n += 1;
    }
}

/// Project the planned graph's Load/Tune/Build tasks (tails stay in
/// the parent) into queue tasks. Ids are graph indices, so
/// done-markers map straight back onto the planned DAG; deps come out
/// id-ascending because `plan` sorts them.
fn queue_tasks_from_graph(
    graph: &TaskGraph,
    specs: &[RunSpec],
    model_fp: &HashMap<String, u64>,
) -> Vec<QueueTask> {
    graph
        .tasks
        .iter()
        .enumerate()
        .filter(|(_, t)| t.kind != StageKind::Tail)
        .map(|(id, t)| QueueTask {
            id,
            kind: t.kind.cached_stage(),
            key: t.key.expect("stage tasks are keyed"),
            spec: specs[t.spec_idx].clone(),
            model_fp: model_fp
                .get(&specs[t.spec_idx].model)
                .copied()
                .unwrap_or(0),
            deps: t
                .deps
                .iter()
                .map(|&d| {
                    let dep = &graph.tasks[d];
                    (
                        d,
                        dep.kind.cached_stage(),
                        dep.key.expect("stage deps are keyed"),
                    )
                })
                .collect(),
        })
        .collect()
}

/// One task as a wire/queue document — the same layout whether it is
/// published as a queue file for local workers or pushed to the serve
/// daemon's task queue for remote ones.
fn task_doc(t: &QueueTask) -> Json {
    let deps = t
        .deps
        .iter()
        .map(|&(d, kind, key)| {
            Json::obj(vec![
                ("id", Json::Num(d as f64)),
                ("kind", Json::Str(kind.name().into())),
                ("key", Json::Str(key.hex())),
            ])
        })
        .collect();
    Json::obj(vec![
        // queue records ride the artifact format's version gate: a
        // worker from another build refuses the queue instead of
        // misreading it
        ("format", Json::Num(persist::FORMAT_VERSION as f64)),
        ("id", Json::Num(t.id as f64)),
        ("kind", Json::Str(t.kind.name().into())),
        ("key", Json::Str(t.key.hex())),
        ("model", Json::Str(t.spec.model.clone())),
        ("model_fp", Json::Str(format!("{:016x}", t.model_fp))),
        ("backend", Json::Str(t.spec.backend.clone())),
        ("target", Json::Str(t.spec.target.clone())),
        (
            "schedule",
            t.spec.schedule.clone().map(Json::Str).unwrap_or(Json::Null),
        ),
        ("tuned", Json::Bool(t.spec.tuned)),
        (
            "features",
            Json::Arr(
                t.spec.features.names().into_iter().map(Json::Str).collect(),
            ),
        ),
        ("deps", Json::Arr(deps)),
    ])
}

/// Publish every stage task as a queue file for the worker processes.
fn publish(queue: &Path, tasks: &[QueueTask]) -> Result<()> {
    for t in tasks {
        write_atomic(
            &queue.join(format!("task-{}.json", t.id)),
            task_doc(t).to_string().as_bytes(),
        )?;
    }
    Ok(())
}

/// Spawn up to `n` worker children. Spawn failures degrade to fewer
/// workers (the parent drains regardless), never to an error.
fn spawn_workers(env: &Environment, queue: &Path, n: usize) -> Vec<Child> {
    let bin = match env.dispatch_worker_bin() {
        Some(p) => p,
        None => match std::env::current_exe() {
            Ok(p) => p,
            Err(e) => {
                crate::log_warn!("dispatch: current_exe unavailable ({e})");
                return Vec::new();
            }
        },
    };
    let mut children = Vec::new();
    for _ in 0..n {
        let mut cmd = Command::new(&bin);
        cmd.arg("worker")
            .arg("--queue")
            .arg(queue)
            .arg("--home")
            .arg(&env.root)
            .stdin(Stdio::null())
            .stdout(Stdio::null()); // stderr inherited: worker logs stay visible
        for (k, v) in &env.overrides {
            cmd.arg("-c").arg(format!("{k}={v}"));
        }
        match cmd.spawn() {
            Ok(c) => children.push(c),
            Err(e) => {
                crate::log_warn!(
                    "dispatch: spawning worker {} failed: {e}",
                    bin.display()
                );
                break;
            }
        }
    }
    children
}

/// Parent-side supervision loop: returns once every task has an
/// outcome. While children live, the parent only reaps them and
/// breaks stale leases (a killed worker's task is reclaimed by a live
/// worker); once the fleet is gone it drains the remainder itself.
fn supervise(ctx: &WorkerCtx, children: &mut Reaper) -> Result<()> {
    // deadline watchdog: how long each lease token has held each task.
    // A hung worker keeps its heartbeat alive — staleness never fires —
    // so past the deadline the parent force-breaks the lease and a live
    // worker (or the parent itself) re-runs the task.
    let deadline_ms = ctx.env.retry_deadline_ms();
    let mut held: HashMap<(usize, String), Stopwatch> = HashMap::new();
    loop {
        // reap exited children so their pids read as dead everywhere
        children.0.retain_mut(|c| !matches!(c.try_wait(), Ok(Some(_))));
        if ctx.tasks.iter().all(|t| done_exists(ctx.queue, t.id)) {
            return Ok(());
        }
        if children.0.is_empty() {
            return drain(ctx);
        }
        for t in &ctx.tasks {
            if done_exists(ctx.queue, t.id) {
                continue;
            }
            let lease = lease_path(ctx.queue, t.id);
            if reclaim_if_stale(&lease, ctx.lease_ms) {
                crate::log_warn!(
                    "dispatch: reclaimed stale lease of task {}",
                    t.id
                );
            } else if deadline_ms > 0
                && lease_past_deadline(&mut held, &lease, t.id, deadline_ms)
                && force_reclaim(&lease)
            {
                crate::log_warn!(
                    "dispatch: task {} exceeded the {}ms stage deadline; \
                     lease revoked for retry elsewhere",
                    t.id,
                    deadline_ms
                );
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Track how long the current token has held a task's lease; true once
/// the same token stays past `deadline_ms`. A token change (reclaim,
/// re-lease) restarts the clock.
fn lease_past_deadline(
    held: &mut HashMap<(usize, String), Stopwatch>,
    lease: &Path,
    id: usize,
    deadline_ms: u64,
) -> bool {
    let Ok(token) = fs::read_to_string(lease) else {
        return false; // no lease: nothing is hung
    };
    let watch =
        held.entry((id, token.trim().to_string())).or_insert_with(Stopwatch::start);
    watch.elapsed_s() * 1000.0 > deadline_ms as f64
}

/// Kills + reaps the worker fleet on drop, so no codepath (including
/// errors) leaks children or zombies.
struct Reaper(Vec<Child>);

impl Drop for Reaper {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

// ------------------------------------------------------------ worker --

/// Entry point of the `mlonmcu worker` subcommand: drain the queue at
/// `queue_dir`, exchanging artifacts through `env`'s store.
pub fn worker_main(queue_dir: &Path, env: &Environment) -> Result<i32> {
    // tracing is session-wide: the parent forwards `trace.file` as a
    // `-c` override, so a traced session traces its whole fleet. Each
    // worker writes its spans to `queue/trace-<pid>.json`; the parent
    // merges those files into the exported timeline.
    let traced = env.trace_file().is_some();
    if traced {
        crate::util::trace::enable();
    }
    // metrics follow the same session-wide scheme: workers record into
    // their own registry and leave `queue/metrics-<pid>.json` behind
    let metered = env.metrics_enabled();
    if metered {
        crate::util::metrics::enable();
    }
    // fault plans travel the same way (`faults.plan` override / config)
    // and `exit` rules only arm in worker processes
    crate::util::faults::set_worker_role();
    if let Some(spec) = env.fault_spec() {
        if let Err(e) = crate::util::faults::install(&spec) {
            crate::log_warn!("worker: fault plan rejected ({e})");
        }
    }
    let store = Arc::new(EnvStore::open_with(
        &env.cache_dir(),
        env.cache_budget_bytes(),
        env.store_lock_stale_ms(),
    )?);
    let ctx = WorkerCtx {
        queue: queue_dir,
        env,
        store,
        tune: scheduler::tune_params(env),
        lease_ms: env.dispatch_lease_ms(),
        tasks: read_queue_tasks(queue_dir)?,
    };
    let result = {
        let _span = crate::util::trace::span("worker", "drain")
            .arg_with("queue", || queue_dir.display().to_string());
        drain(&ctx)
    };
    if traced {
        let path = queue_dir.join(crate::util::trace::worker_file_name());
        let spans = crate::util::trace::drain();
        if let Err(e) = crate::util::trace::write_spans(&path, spans) {
            crate::log_warn!("worker: trace spans not written ({e:#})");
        }
    }
    if metered {
        let path = queue_dir.join(crate::util::metrics::worker_file_name());
        let snap = crate::util::metrics::drain();
        if let Err(e) = crate::util::metrics::write_snapshot(&path, &snap) {
            crate::log_warn!("worker: metrics not written ({e:#})");
        }
    }
    result?;
    Ok(0)
}

/// Parse every published task. Rejects queues written by a different
/// artifact-format version and dangling dependency ids up front.
fn read_queue_tasks(queue: &Path) -> Result<Vec<QueueTask>> {
    let mut tasks: Vec<QueueTask> = Vec::new();
    let dir = fs::read_dir(queue)
        .with_context(|| format!("reading queue {}", queue.display()))?;
    for f in dir.flatten() {
        let name = f.file_name();
        let Some(id) = name
            .to_str()
            .and_then(|n| n.strip_prefix("task-"))
            .and_then(|n| n.strip_suffix(".json"))
            .and_then(|n| n.parse::<usize>().ok())
        else {
            continue; // leases, done markers, tmp files
        };
        let doc = Json::parse_file(&f.path())
            .with_context(|| format!("parsing queue task {id}"))?;
        tasks.push(parse_task(id, &doc)?);
    }
    tasks.sort_by_key(|t| t.id);
    // dangling dep = corrupt queue; better to refuse than to hang
    for t in &tasks {
        for &(d, _, _) in &t.deps {
            if !tasks.iter().any(|o| o.id == d) {
                bail!("queue task {} depends on missing task {d}", t.id);
            }
        }
    }
    Ok(tasks)
}

fn parse_task(id: usize, j: &Json) -> Result<QueueTask> {
    let format = j.get("format").and_then(Json::as_i64).unwrap_or(-1);
    if format != persist::FORMAT_VERSION as i64 {
        bail!(
            "queue task {id}: format {format} != {} (worker from a \
             different build?)",
            persist::FORMAT_VERSION
        );
    }
    let str_field = |k: &str| -> Result<String> {
        Ok(j.get(k)
            .and_then(Json::as_str)
            .with_context(|| format!("queue task {id}: missing '{k}'"))?
            .to_string())
    };
    let kind = CachedStage::from_name(&str_field("kind")?)
        .with_context(|| format!("queue task {id}: bad kind"))?;
    let key = parse_key(j.get("key").and_then(Json::as_str))
        .with_context(|| format!("queue task {id}: bad key"))?;
    let features: Vec<String> = j
        .get("features")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .filter_map(|v| v.as_str().map(str::to_string))
        .collect();
    let mut deps = Vec::new();
    for d in j.get("deps").and_then(Json::as_arr).unwrap_or(&[]) {
        let did = d
            .get("id")
            .and_then(Json::as_i64)
            .with_context(|| format!("queue task {id}: bad dep id"))?;
        let dkind = CachedStage::from_name(
            d.get("kind").and_then(Json::as_str).unwrap_or(""),
        )
        .with_context(|| format!("queue task {id}: bad dep kind"))?;
        let dkey = parse_key(d.get("key").and_then(Json::as_str))
            .with_context(|| format!("queue task {id}: bad dep key"))?;
        deps.push((did.max(0) as usize, dkind, dkey));
    }
    deps.sort_by_key(|&(d, _, _)| d);
    Ok(QueueTask {
        id,
        kind,
        key,
        model_fp: j
            .get("model_fp")
            .and_then(Json::as_str)
            .and_then(|h| u64::from_str_radix(h, 16).ok())
            .unwrap_or(0),
        spec: RunSpec {
            model: str_field("model")?,
            backend: str_field("backend")?,
            target: str_field("target")?,
            schedule: j
                .get("schedule")
                .and_then(Json::as_str)
                .map(str::to_string),
            tuned: matches!(j.get("tuned"), Some(Json::Bool(true))),
            features: Features::parse(&features)?,
        },
        deps,
    })
}

fn parse_key(hex: Option<&str>) -> Option<StageKey> {
    u64::from_str_radix(hex?, 16).ok().map(StageKey)
}

/// Claim/execute loop shared by worker processes and the assisting
/// parent. Returns once every task has a done marker. A task outcome
/// that cannot be published (disk full, unwritable queue) is a hard
/// error — retrying would re-execute the stage forever.
fn drain(ctx: &WorkerCtx) -> Result<()> {
    loop {
        let mut all_done = true;
        let mut progressed = false;
        for t in &ctx.tasks {
            if done_exists(ctx.queue, t.id) {
                continue;
            }
            all_done = false;
            if !t.deps.iter().all(|&(d, _, _)| done_exists(ctx.queue, d)) {
                continue;
            }
            match Lease::claim(ctx.queue, t.id, ctx.lease_ms) {
                Some(_lease) => {
                    execute_task(ctx, t)?;
                    progressed = true;
                    // done marker written; lease released on drop
                }
                None => {
                    // claimed elsewhere: reclaim if its owner is dead
                    // or stopped heartbeating
                    if reclaim_if_stale(
                        &lease_path(ctx.queue, t.id),
                        ctx.lease_ms,
                    ) {
                        crate::log_warn!(
                            "dispatch: reclaimed stale lease of task {}",
                            t.id
                        );
                        progressed = true;
                    }
                }
            }
        }
        if all_done {
            return Ok(());
        }
        if !progressed {
            std::thread::sleep(Duration::from_millis(15));
        }
    }
}

/// Execute one claimed task and write its done marker. Never panics
/// out (stage panics become failed outcomes, scheduler-style); only
/// an unpublishable outcome is an error.
fn execute_task(ctx: &WorkerCtx, t: &QueueTask) -> Result<()> {
    let done = run_stage_task(ctx, t);
    write_done_once(ctx.queue, t.id, &done)
        .with_context(|| format!("publishing outcome of task {}", t.id))
}

/// Publish a done marker atomically, first-writer-wins: a duplicate
/// execution (possible after a racy lease reclaim) must not overwrite
/// the original record — the first outcome is the one the parent's
/// accounting replays. `hard_link` both publishes atomically and
/// refuses an existing destination.
fn write_done_once(queue: &Path, id: usize, done: &DoneRecord) -> Result<()> {
    let path = done_path(queue, id);
    if path.exists() {
        return Ok(()); // a duplicate already settled this task
    }
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    fs::write(&tmp, done.to_json(id).to_string().as_bytes())
        .with_context(|| format!("writing {}", tmp.display()))?;
    let linked = fs::hard_link(&tmp, &path);
    let _ = fs::remove_file(&tmp);
    match linked {
        Ok(()) => Ok(()),
        // lost the publish race: the earlier record wins
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(()),
        // re-check before the rename fallback, which WOULD overwrite
        Err(_) if path.exists() => Ok(()),
        // filesystem without hard links: fall back to tmp-rename
        Err(_) => write_atomic(&path, done.to_json(id).to_string().as_bytes()),
    }
}

fn run_stage_task(ctx: &WorkerCtx, t: &QueueTask) -> DoneRecord {
    // propagate upstream failures without executing — deps are
    // id-ordered, matching the serial scheduler's earliest-dep pick
    for &(d, _, _) in &t.deps {
        if let Some(dep) = read_done(ctx.queue, d) {
            if !dep.ok {
                return DoneRecord::failed(&dep.stage, dep.error, Lookup::None, 0.0);
            }
        }
    }
    let mut span = crate::util::trace::span("stage", t.kind.name())
        .arg_with("task", || t.id.to_string())
        .arg_with("backend", || t.spec.backend.clone())
        .arg_with("schedule", || {
            t.spec.schedule.clone().unwrap_or_else(|| "default".into())
        });
    // primary lookup: another invocation (or worker round) may have
    // produced this artifact already
    let faults_before = crate::util::faults::injected_count();
    let lookup = match ctx.store.load(t.key, t.kind) {
        StoreLookup::Hit(_) => {
            span.note("outcome", "hit");
            let mut done = DoneRecord::ok(false, Lookup::Hit, 0.0);
            done.faults = task_faults(faults_before);
            return done;
        }
        StoreLookup::Miss => Lookup::Miss,
        StoreLookup::Corrupt => Lookup::Corrupt,
    };
    let watch = Stopwatch::start();
    // bounded retry with backoff; panics are caught per attempt and the
    // exhausted error carries the quarantine [attempts=N] marker
    let result = scheduler::with_retry(
        ctx.env.retry_attempts(),
        ctx.env.retry_backoff_ms(),
        t.kind.name(),
        || execute_stage(ctx, t),
    );
    let secs = watch.elapsed_s();
    crate::util::metrics::observe(
        crate::util::metrics::stage_metric(t.kind.name()),
        (secs * 1e6) as u64,
    );
    let mut done = match result {
        Ok(artifact) => {
            if let Err(e) = ctx.store.save(t.key, &artifact) {
                crate::log_warn!(
                    "dispatch: artifact {} not saved: {e}",
                    t.key.hex()
                );
            }
            DoneRecord::ok(true, lookup, secs)
        }
        Err(e) => DoneRecord::failed(
            t.kind.name(),
            e.to_string(),
            lookup,
            secs,
        ),
    };
    done.faults = task_faults(faults_before);
    span.note("outcome", if done.ok { "ok" } else { "failed" });
    done
}

fn execute_stage(ctx: &WorkerCtx, t: &QueueTask) -> Result<Artifact> {
    match t.kind {
        CachedStage::Load => run::stage_load(ctx.env, &t.spec)
            .map(|g| Artifact::Graph(Arc::new(g))),
        CachedStage::Tune => {
            let graph = fetch_graph(ctx, t)?;
            run::stage_tune(&t.spec, &graph, ctx.tune).map(Artifact::Tune)
        }
        CachedStage::Build => {
            let graph = fetch_graph(ctx, t)?;
            let tuned = fetch_tune(ctx, t, &graph)?;
            run::stage_build(&t.spec, &graph, tuned.map(|o| o.schedule))
                .map(|b| Artifact::Build(Arc::new(b)))
        }
    }
}

/// The Load dep's graph from the store; recomputed locally when the
/// store lost it (budget eviction between producer and consumer).
fn fetch_graph(ctx: &WorkerCtx, t: &QueueTask) -> Result<Arc<crate::graph::Graph>> {
    for &(_, kind, key) in &t.deps {
        if kind == CachedStage::Load {
            if let StoreLookup::Hit(Artifact::Graph(g)) =
                ctx.store.load(key, CachedStage::Load)
            {
                return Ok(g);
            }
        }
    }
    run::stage_load(ctx.env, &t.spec).map(Arc::new)
}

/// The Tune dep's outcome, when this build consumes one.
fn fetch_tune(
    ctx: &WorkerCtx,
    t: &QueueTask,
    graph: &crate::graph::Graph,
) -> Result<Option<TuneOutcome>> {
    let Some(&(_, _, key)) =
        t.deps.iter().find(|&&(_, k, _)| k == CachedStage::Tune)
    else {
        return Ok(None);
    };
    if let StoreLookup::Hit(Artifact::Tune(o)) =
        ctx.store.load(key, CachedStage::Tune)
    {
        return Ok(Some(o));
    }
    run::stage_tune(&t.spec, graph, ctx.tune).map(Some)
}

// ----------------------------------------------------- queue files --

fn done_path(queue: &Path, id: usize) -> PathBuf {
    queue.join(format!("task-{id}.done.json"))
}

fn lease_path(queue: &Path, id: usize) -> PathBuf {
    queue.join(format!("task-{id}.lease"))
}

fn done_exists(queue: &Path, id: usize) -> bool {
    done_path(queue, id).exists()
}

fn read_done(queue: &Path, id: usize) -> Option<DoneRecord> {
    let doc = Json::parse_file(&done_path(queue, id)).ok()?;
    DoneRecord::from_json(&doc)
}

/// Process-wide monotonic nonce for lease tokens.
fn next_nonce() -> u64 {
    static NONCE: AtomicU64 = AtomicU64::new(0);
    NONCE.fetch_add(1, Ordering::Relaxed)
}

/// A held task lease: the `.lease` file plus a heartbeat thread that
/// rewrites it every `lease_ms / 4`, so a live owner's lease never
/// looks stale. Dropping stops the heartbeat and unlinks the lease
/// (only if still owned — a reclaimer may have replaced it).
struct Lease {
    path: PathBuf,
    token: String,
    stop: Arc<AtomicBool>,
    heartbeat: Option<std::thread::JoinHandle<()>>,
    /// Trace span covering the whole hold (claim win → release); lost
    /// claim attempts record nothing, so contention stays off traces.
    _span: crate::util::trace::SpanGuard,
    /// Metered hold duration, observed as `lease.hold.us` on release.
    hold: crate::util::metrics::Clock,
}

impl Lease {
    /// Atomically claim task `id`; `None` when someone else holds it.
    fn claim(queue: &Path, id: usize, lease_ms: u64) -> Option<Lease> {
        use std::io::Write as _;
        let path = lease_path(queue, id);
        let token = format!("{}-{:x}", std::process::id(), next_nonce());
        let mut f = fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path)
            .ok()?;
        let span = crate::util::trace::span("lease", "claim")
            .arg_with("task", || id.to_string());
        let _ = f.write_all(token.as_bytes());
        drop(f);
        let stop = Arc::new(AtomicBool::new(false));
        let heartbeat = {
            let (path, token, stop) = (path.clone(), token.clone(), stop.clone());
            let beat = Duration::from_millis((lease_ms / 4).max(10));
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(beat);
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    // an injected stall sleeps here, so the lease ages
                    // out and a peer (or the parent) reclaims the task
                    crate::util::faults::fire("queue.lease.heartbeat");
                    // touch (rewrite) ONLY a lease that is still ours:
                    // recreating a reclaimed-and-re-claimed lease would
                    // hand our token back to Drop, which would then
                    // unlink the new owner's live lease
                    match fs::read_to_string(&path) {
                        Ok(s) if s.trim() == token => {
                            let _beat =
                                crate::util::trace::span("lease", "heartbeat");
                            let touch = crate::util::metrics::clock();
                            let _ = fs::write(&path, token.as_bytes());
                            touch.observe("lease.heartbeat.us");
                        }
                        _ => break, // lost ownership: stop touching it
                    }
                }
            })
        };
        Some(Lease {
            path,
            token,
            stop,
            heartbeat: Some(heartbeat),
            _span: span,
            hold: crate::util::metrics::clock(),
        })
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.heartbeat.take() {
            let _ = h.join();
        }
        let ours = fs::read_to_string(&self.path)
            .is_ok_and(|s| s.trim() == self.token);
        if ours {
            let _ = fs::remove_file(&self.path);
        }
        self.hold.observe("lease.hold.us");
    }
}

/// Is this lease stale? Immediately when its recorded pid is dead
/// (crashed/killed owner — it has no writes in flight), otherwise
/// after `lease_ms` without a heartbeat. Same rules as the store's
/// lock file (`util::proc::stale_owner_file`).
fn lease_is_stale(path: &Path, lease_ms: u64) -> bool {
    stale_owner_file(path, Duration::from_millis(lease_ms.max(100)))
}

/// Break a stale lease via rename-to-unique (exactly one of several
/// concurrent reclaimers wins; a fresh lease created in the meantime
/// is never touched). Returns whether the task became claimable.
fn reclaim_if_stale(path: &Path, lease_ms: u64) -> bool {
    if !lease_is_stale(path, lease_ms) {
        return false;
    }
    force_reclaim(path)
}

/// Break a lease unconditionally (staleness already established, or
/// the deadline watchdog evicting a hung-but-heartbeating owner). The
/// evicted owner's heartbeat stops at its next token check, and
/// first-writer-wins done markers absorb any late result it publishes.
fn force_reclaim(path: &Path) -> bool {
    let grave = path.with_extension(format!(
        "stale.{}-{:x}",
        std::process::id(),
        next_nonce()
    ));
    if fs::rename(path, &grave).is_ok() {
        let _ = fs::remove_file(&grave);
        return true;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlonmcu_dispatch_{tag}"));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn lease_claim_is_exclusive_and_released_on_drop() {
        let dir = tmp("lease");
        let a = Lease::claim(&dir, 0, 5000).expect("first claim wins");
        assert!(Lease::claim(&dir, 0, 5000).is_none(), "second claim loses");
        drop(a);
        assert!(!lease_path(&dir, 0).exists(), "released on drop");
        assert!(Lease::claim(&dir, 0, 5000).is_some(), "claimable again");
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn dead_pid_lease_is_reclaimed_immediately() {
        let dir = tmp("deadlease");
        let dead = {
            let mut c = std::process::Command::new("true").spawn().unwrap();
            let pid = c.id();
            c.wait().unwrap();
            pid
        };
        fs::write(lease_path(&dir, 3), format!("{dead}-1")).unwrap();
        // lease_ms is huge: only the dead-pid path can fire
        assert!(reclaim_if_stale(&lease_path(&dir, 3), 600_000));
        assert!(!lease_path(&dir, 3).exists());
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn live_lease_is_not_reclaimed() {
        let dir = tmp("livelease");
        let _l = Lease::claim(&dir, 1, 600_000).unwrap();
        assert!(!reclaim_if_stale(&lease_path(&dir, 1), 600_000));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn heartbeat_keeps_mtime_fresh() {
        let dir = tmp("heartbeat");
        let _l = Lease::claim(&dir, 2, 80).unwrap(); // beat every 20ms
        std::thread::sleep(Duration::from_millis(400));
        // the mtime-staleness threshold (150ms) is far exceeded by the
        // sleep — only a live heartbeat keeps the lease fresh (the pid
        // check can't save it: age is tested before the pid)
        assert!(!lease_is_stale(&lease_path(&dir, 2), 150));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn done_marker_is_first_writer_wins() {
        let dir = tmp("donewins");
        let first = DoneRecord::ok(true, Lookup::Miss, 1.0);
        write_done_once(&dir, 5, &first).unwrap();
        // a racy duplicate execution reports a store hit — it must NOT
        // replace the original executed=true record
        let second = DoneRecord::ok(false, Lookup::Hit, 0.0);
        write_done_once(&dir, 5, &second).unwrap();
        let back = read_done(&dir, 5).unwrap();
        assert!(back.executed, "first record wins");
        assert_eq!(back.lookup, Lookup::Miss);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn task_doc_roundtrips_through_parse_task() {
        let t = QueueTask {
            id: 4,
            kind: CachedStage::Build,
            key: StageKey(0xabcd),
            spec: RunSpec {
                model: "m.tmodel".into(),
                backend: "tflmi".into(),
                target: "etiss".into(),
                schedule: None,
                tuned: true,
                features: Features::parse(&[]).unwrap(),
            },
            model_fp: 0x1234_5678_9abc_def0,
            deps: vec![
                (1, CachedStage::Load, StageKey(7)),
                (2, CachedStage::Tune, StageKey(9)),
            ],
        };
        let back = parse_task(4, &task_doc(&t)).unwrap();
        assert_eq!(back.model_fp, t.model_fp);
        assert_eq!(back.key, t.key);
        assert_eq!(back.deps, t.deps);
        assert!(back.spec.tuned);
        assert_eq!(back.spec.model, "m.tmodel");
    }

    #[test]
    fn done_record_roundtrips() {
        let ok = DoneRecord::ok(true, Lookup::Miss, 1.25);
        let j = ok.to_json(7);
        let back = DoneRecord::from_json(&Json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert!(back.ok && back.executed);
        assert_eq!(back.lookup, Lookup::Miss);
        assert_eq!(back.secs, 1.25);

        let bad = DoneRecord::failed("tune", "no tuning".into(), Lookup::None, 0.0);
        let back =
            DoneRecord::from_json(&Json::parse(&bad.to_json(1).to_string()).unwrap())
                .unwrap();
        assert!(!back.ok);
        assert_eq!((back.stage.as_str(), back.error.as_str()), ("tune", "no tuning"));
        assert_eq!(back.lookup, Lookup::None);
    }
}
