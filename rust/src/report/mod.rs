//! Reports (paper §II-A3 "Evaluate"): the tabular output of a
//! session. A `Report` is an ordered list of rows (one per run) with
//! dynamic columns; postprocesses transform it; renderers emit
//! markdown, CSV and paper-style tables.

use std::collections::BTreeMap;

use crate::data::csv::to_csv;

/// One report cell.
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    Str(String),
    Int(i64),
    Float(f64),
    /// A failed run ("—" in Table V).
    Missing,
}

impl Cell {
    pub fn render(&self) -> String {
        match self {
            Cell::Str(s) => s.clone(),
            Cell::Int(x) => x.to_string(),
            Cell::Float(x) => {
                if x.abs() >= 1000.0 {
                    format!("{x:.0}")
                } else {
                    format!("{x:.4}")
                }
            }
            Cell::Missing => "—".to_string(),
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Cell::Int(x) => Some(*x as f64),
            Cell::Float(x) => Some(*x),
            _ => None,
        }
    }
}

/// One run's row: ordered key → cell map.
pub type Row = BTreeMap<String, Cell>;

/// A session report.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Column order (columns appear as first encountered).
    pub columns: Vec<String>,
    pub rows: Vec<Row>,
    /// Session-level footer lines (cache summary, ...); rendered by
    /// the markdown/text writers, excluded from CSV (whose consumers
    /// expect pure tabular data).
    pub notes: Vec<String>,
}

impl Report {
    pub fn push(&mut self, row: Row) {
        for k in row.keys() {
            if !self.columns.contains(k) {
                self.columns.push(k.clone());
            }
        }
        self.rows.push(row);
    }

    /// Append a footer note (rendered by the markdown/text writers,
    /// never by CSV).
    pub fn note(&mut self, note: impl Into<String>) {
        self.notes.push(note.into());
    }

    pub fn merge(&mut self, other: Report) {
        for row in other.rows {
            self.push(row);
        }
        self.notes.extend(other.notes);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Keep only the listed columns (filter_cols postprocess).
    pub fn select(&self, cols: &[&str]) -> Report {
        let columns: Vec<String> = cols
            .iter()
            .filter(|c| self.columns.iter().any(|x| x == *c))
            .map(|c| c.to_string())
            .collect();
        let rows = self
            .rows
            .iter()
            .map(|r| {
                columns
                    .iter()
                    .filter_map(|c| r.get(c).map(|v| (c.clone(), v.clone())))
                    .collect()
            })
            .collect();
        Report { columns, rows, notes: self.notes.clone() }
    }

    fn cell(&self, row: &Row, col: &str) -> String {
        row.get(col).map_or(String::new(), |c| c.render())
    }

    /// GitHub-flavoured markdown table.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push('|');
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push_str("\n|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for row in &self.rows {
            s.push('|');
            for c in &self.columns {
                s.push_str(&format!(" {} |", self.cell(row, c)));
            }
            s.push('\n');
        }
        for n in &self.notes {
            s.push_str(&format!("\n> {n}\n"));
        }
        s
    }

    /// CSV artifact.
    pub fn to_csv(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| self.columns.iter().map(|c| self.cell(r, c)).collect())
            .collect();
        to_csv(&self.columns, &rows)
    }

    /// Fixed-width plain-text table (CLI output).
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in self.columns.iter().enumerate() {
                widths[i] = widths[i].max(self.cell(row, c).len());
            }
        }
        let mut s = String::new();
        for (i, c) in self.columns.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        s.push('\n');
        for (i, _) in self.columns.iter().enumerate() {
            s.push_str(&"-".repeat(widths[i]));
            s.push_str("  ");
        }
        s.push('\n');
        for row in &self.rows {
            for (i, c) in self.columns.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", self.cell(row, c), w = widths[i]));
            }
            s.push('\n');
        }
        for n in &self.notes {
            s.push_str(&format!("{n}\n"));
        }
        s
    }
}

/// Row-building convenience.
pub fn row(pairs: Vec<(&str, Cell)>) -> Row {
    pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::default();
        r.push(row(vec![
            ("model", Cell::Str("aww".into())),
            ("rom_kb", Cell::Float(143.2)),
        ]));
        r.push(row(vec![
            ("model", Cell::Str("vww".into())),
            ("rom_kb", Cell::Missing),
        ]));
        r
    }

    #[test]
    fn markdown_and_text_contain_cells() {
        let r = sample();
        let md = r.to_markdown();
        assert!(md.contains("| aww |"));
        assert!(md.contains("—"));
        let txt = r.to_text();
        assert!(txt.contains("aww"));
    }

    #[test]
    fn notes_render_in_markdown_and_text_not_csv() {
        let mut r = sample();
        r.notes.push("cache: 3 hits".into());
        assert!(r.to_markdown().contains("> cache: 3 hits"));
        assert!(r.to_text().contains("cache: 3 hits"));
        assert!(!r.to_csv().contains("cache: 3 hits"));
        assert_eq!(r.select(&["model"]).notes.len(), 1);
    }

    #[test]
    fn csv_roundtrip() {
        let r = sample();
        let parsed = crate::data::csv::parse_csv(&r.to_csv());
        assert_eq!(parsed[0], vec!["model", "rom_kb"]);
        assert_eq!(parsed[1][0], "aww");
    }

    #[test]
    fn select_filters_columns() {
        let r = sample().select(&["model", "nosuch"]);
        assert_eq!(r.columns, vec!["model"]);
        assert_eq!(r.rows[0].len(), 1);
    }

    #[test]
    fn merge_unions_columns() {
        let mut a = sample();
        let mut b = Report::default();
        b.push(row(vec![
            ("model", Cell::Str("x".into())),
            ("extra", Cell::Int(1)),
        ]));
        a.merge(b);
        assert!(a.columns.contains(&"extra".to_string()));
        assert_eq!(a.len(), 3);
    }
}
