//! Minimal property-testing framework (proptest is not reachable
//! offline): seeded random case generation with iteration counts and
//! greedy input shrinking for failing cases. Used by the coordinator
//! invariant tests in rust/tests/.

use crate::util::XorShift64;

/// Configuration for a property check.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0xB0B }
    }
}

/// Check `prop` over `cases` generated inputs; on failure, greedily
/// shrink via `shrink` and panic with the minimal failing input.
pub fn check<T, G, S, P>(cfg: Config, mut gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut XorShift64) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    let mut rng = XorShift64::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            // shrink greedily: first shrink candidate that still fails
            let mut minimal = input.clone();
            'outer: loop {
                for cand in shrink(&minimal) {
                    if !prop(&cand) {
                        minimal = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case} (seed {}):\n  original: {input:?}\n  minimal:  {minimal:?}",
                cfg.seed
            );
        }
    }
}

/// No-shrink helper.
pub fn no_shrink<T>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Shrinker for Vec<T>: drop halves, then drop single elements.
pub fn shrink_vec<T: Clone>(v: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    // every candidate must be STRICTLY smaller, or shrinking loops
    if n / 2 < n {
        out.push(v[..n / 2].to_vec());
    }
    if n - n / 2 < n {
        out.push(v[n / 2..].to_vec());
    }
    for i in 0..n.min(8) {
        let mut c = v.clone();
        c.remove(i);
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check(
            Config { cases: 50, seed: 1 },
            |rng| rng.range(0, 100),
            no_shrink,
            |&x| x <= 100,
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        check(
            Config { cases: 200, seed: 2 },
            |rng| (0..rng.range(0, 20)).map(|_| rng.range(0, 50)).collect::<Vec<_>>(),
            shrink_vec,
            |v| v.iter().sum::<usize>() < 40, // fails for big vectors
        );
    }

    #[test]
    fn shrink_vec_produces_smaller_candidates() {
        let v = vec![1, 2, 3, 4];
        for c in shrink_vec(&v) {
            assert!(c.len() < v.len());
        }
    }
}
