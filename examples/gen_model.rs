//! Generate a tiny self-contained `.tmodel` file with the rust-side
//! writer — no python toolchain needed. Used by the CI
//! `cache-persistence` and hotpath-bench jobs to seed an
//! environment's model zoo before driving the CLI/benches, and handy
//! for local smoke tests:
//!
//! ```sh
//! cargo run --release --example gen_model -- path/to/tinyconv.tmodel
//! cargo run --release --example gen_model -- path/to/tinymlp.tmodel tinymlp
//! ```
//!
//! Variants: `tinyconv` (default; input[1,4,4,2] → conv 3ch 3×3 SAME
//! relu → out[1,4,4,3]) and `tinymlp` (conv → maxpool → reshape →
//! dense → softmax — a deeper pipeline for the hotpath bench). Both
//! are small enough to pass every hardware target's memory gates.

use std::path::PathBuf;

use mlonmcu::frontends::tmodel;
use mlonmcu::graph::{Graph, OpCode, OpNode, TensorInfo, ACT_RELU, PAD_SAME};
use mlonmcu::tensor::DType;

fn tiny_conv_graph() -> Graph {
    let mut attrs = std::collections::BTreeMap::new();
    attrs.insert("stride_h".to_string(), 1);
    attrs.insert("stride_w".to_string(), 1);
    attrs.insert("padding".to_string(), PAD_SAME);
    attrs.insert("fused_act".to_string(), ACT_RELU);
    Graph {
        name: "tinyconv".into(),
        tensors: vec![
            TensorInfo {
                name: "input".into(),
                shape: vec![1, 4, 4, 2],
                dtype: DType::I8,
                scale: 0.5,
                zero_point: 0,
                data: None,
            },
            TensorInfo {
                name: "w".into(),
                shape: vec![3, 3, 3, 2],
                dtype: DType::I8,
                scale: 0.01,
                zero_point: 0,
                data: Some((0..54).map(|x| (x % 7) as u8).collect()),
            },
            TensorInfo {
                name: "b".into(),
                shape: vec![3],
                dtype: DType::I32,
                scale: 0.005,
                zero_point: 0,
                data: Some(vec![0; 12]),
            },
            TensorInfo {
                name: "out".into(),
                shape: vec![1, 4, 4, 3],
                dtype: DType::I8,
                scale: 0.25,
                zero_point: -128,
                data: None,
            },
        ],
        ops: vec![OpNode {
            opcode: OpCode::Conv2D,
            name: "conv0".into(),
            inputs: vec![0, 1, 2],
            outputs: vec![3],
            attrs,
        }],
        inputs: vec![0],
        outputs: vec![3],
    }
}

fn main() -> anyhow::Result<()> {
    let path = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("tinyconv.tmodel"));
    let variant = std::env::args().nth(2).unwrap_or_else(|| "tinyconv".into());
    let graph = match variant.as_str() {
        "tinyconv" => tiny_conv_graph(),
        "tinymlp" => mlonmcu::graph::model::testutil::tiny_mlp(),
        other => anyhow::bail!("unknown model variant '{other}'"),
    };
    graph.validate()?;
    tmodel::write_file(&graph, &path)?;
    println!(
        "wrote {} ({} params, {} MACs)",
        path.display(),
        graph.param_count(),
        graph.macs()
    );
    Ok(())
}
