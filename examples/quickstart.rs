//! Quickstart: benchmark one model with one backend on one target and
//! print the report — the "single benchmark" flow of paper §II-A2.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use mlonmcu::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. resolve the active environment (MLONMCU_HOME / cwd / default)
    let env = Environment::discover()?;

    // 2. create an isolated session (artifacts under
    //    artifacts/sessions/<id>/)
    let session = Session::new(&env)?;

    // 3. define the benchmark: keyword spotting, TVM AoT, RISC-V ISS,
    //    with golden-output validation through PJRT
    let matrix = RunMatrix::new()
        .models(["aww"])
        .backends(["tvmaot"])
        .targets(["etiss"])
        .features(["validate"]);

    // 4. run and print
    let report = session.run_matrix(&matrix, 1)?;
    println!("{}", report.to_text());

    let t = *session.last_timing.lock().unwrap();
    println!(
        "1 run in {:.2}s — artifacts in {}",
        t.wall_s,
        session.dir.display()
    );
    Ok(())
}
