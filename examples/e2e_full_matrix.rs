//! END-TO-END driver (DESIGN.md experiment V1 + the paper's headline
//! claim): regenerate the full 118-comparison campaign — 20
//! backend-comparison runs on the ISS (§III-B) plus the ~98-result
//! schedule study on four virtual boards (§III-C) — through the
//! complete three-layer stack:
//!
//!   * models come from the python zoo (.tmodel artifacts),
//!   * every ISS run is validated against the JAX/Pallas golden path
//!     executed via PJRT (the `validate` feature),
//!   * hardware runs execute numerically on the virtual MCUs through
//!     the Zephyr-sim platform and MLIF serial protocol.
//!
//! Prints the paper-vs-ours summary and writes both session reports.
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_full_matrix
//! ```

use mlonmcu::prelude::*;
use mlonmcu::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let env = Environment::discover()?
        .with_overrides(&["tune.trials=150".into()])?;
    let watch = Stopwatch::start();

    // ---- campaign III-B: 20 backend runs on etiss, validated -------
    let session_b = Session::new(&env)?;
    let matrix_b = RunMatrix::new()
        .models(["aww", "vww", "resnet", "toycar"])
        .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
        .targets(["etiss"])
        .features(["validate"]);
    let report_b = session_b.run_matrix(&matrix_b, 2)?;
    let timing_b = *session_b.last_timing.lock().unwrap();

    let ok_b = count(&report_b, |s| s == "ok");
    let validated = report_b
        .rows
        .iter()
        .filter(|r| r["validate"].render().starts_with("pass"))
        .count();
    println!(
        "III-B: {}/{} runs ok, {}/{} outputs validated against the \
         JAX/Pallas golden path (PJRT)",
        ok_b,
        report_b.len(),
        validated,
        report_b.len()
    );
    assert_eq!(ok_b, 20, "all III-B runs must succeed");
    assert_eq!(validated, 20, "all III-B outputs must match golden");

    // ---- campaign III-C: schedules × targets × tuning --------------
    let session_c = Session::new(&env)?;
    let matrix_c = RunMatrix::new()
        .models(["aww", "vww", "resnet", "toycar"])
        .backends(["tvmaot"])
        .targets(["esp32c3", "stm32f4", "stm32f7", "esp32"])
        .schedules(["default-nhwc", "default-nchw", "arm-nhwc", "arm-nchw"])
        .with_tuning_sweep();
    let report_c = session_c.run_matrix(&matrix_c, 2)?;
    let timing_c = *session_c.last_timing.lock().unwrap();

    let ok_c = count(&report_c, |s| s == "ok");
    println!(
        "III-C: {}/{} run attempts ok ({} '—' cells from memory gates \
         and the esp32 tuning limitation; paper reports ~98 results of 128 cells)",
        ok_c,
        report_c.len(),
        report_c.len() - ok_c
    );
    assert!(report_c.len() == 128, "Table V grid is 4x4x4x2");
    assert!(
        (80..=110).contains(&ok_c),
        "successful Table V cells should be ~98, got {ok_c}"
    );

    // ---- headline -----------------------------------------------------
    let total = ok_b + ok_c;
    println!(
        "\n=== {} end-to-end comparisons in {:.1} s wall (paper: 118 \
         comparisons in <60 min on real boards; our devices are simulated \
         — {:.0} s of simulated device time) ===",
        total,
        watch.elapsed_s(),
        timing_b.sim_s + timing_c.sim_s,
    );
    println!(
        "artifact cache: {} stage executions avoided ({} + {} hits), \
         {} + {} builds actually run",
        timing_b.cache_hits + timing_c.cache_hits,
        timing_b.cache_hits,
        timing_c.cache_hits,
        timing_b.stage_execs.builds,
        timing_c.stage_execs.builds,
    );
    println!(
        "reports: {} and {}",
        session_b.dir.join("report.md").display(),
        session_c.dir.join("report.md").display()
    );
    Ok(())
}

fn count(report: &Report, pred: impl Fn(&str) -> bool) -> usize {
    report
        .rows
        .iter()
        .filter(|r| pred(&r["status"].render()))
        .count()
}
