//! Schedule exploration (paper §III-C): sweep TVM schedules and
//! layouts for one model across all four hardware targets, with
//! AutoTVM tuning — the Table V flow on the public API, including the
//! failure cells ("—") produced by memory gates and the esp32's
//! missing tuning support.
//!
//! ```sh
//! make artifacts && cargo run --release --example schedule_explorer -- resnet
//! ```

use mlonmcu::prelude::*;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet".into());
    let env = Environment::discover()?;
    let session = Session::new(&env)?;

    let matrix = RunMatrix::new()
        .models([model.as_str()])
        .backends(["tvmaot"])
        .targets(["esp32c3", "stm32f4", "stm32f7", "esp32"])
        .schedules(["default-nhwc", "default-nchw", "arm-nhwc", "arm-nchw"])
        .with_tuning_sweep();

    // fewer trials than the paper's 600 for an interactive example
    let env = env.with_overrides(&["tune.trials=100".into()])?;
    let session_env = Session::new(&env)?;
    let _ = session; // keep the first session dir for comparison runs

    let report = session_env.run_matrix(&matrix, 2)?;
    let view = report.select(&[
        "model", "target", "schedule", "tuned", "status", "time_s", "tune_gain",
    ]);
    println!("{}", view.to_text());

    let failed = report
        .rows
        .iter()
        .filter(|r| r["status"].render() != "ok")
        .count();
    println!(
        "{} runs, {} failed (memory gates / esp32 tuning) — the paper's \
         Table V '—' cells",
        report.len(),
        failed
    );
    Ok(())
}
