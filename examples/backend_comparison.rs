//! Backend comparison (paper §III-B): all five deployment backends on
//! the ETISS instruction-set simulator for every MLPerf-Tiny model —
//! a user-facing version of the Table IV campaign built on the public
//! session API, with a filtered + sorted report and a bar chart
//! artifact via postprocesses.
//!
//! ```sh
//! make artifacts && cargo run --release --example backend_comparison
//! ```

use mlonmcu::postprocess;
use mlonmcu::prelude::*;

fn main() -> anyhow::Result<()> {
    let env = Environment::discover()?;
    let session = Session::new(&env)?;
    let matrix = RunMatrix::new()
        .models(["aww", "vww", "resnet", "toycar"])
        .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
        .targets(["etiss"])
        .features(["validate"]);

    let mut report = session.run_matrix(&matrix, 2)?;

    // postprocess pipeline: trim to the Table IV columns, sort by
    // invoke cost, and emit an ASCII chart artifact
    let artifacts = postprocess::apply_all(
        &[
            "filter_cols:model,backend,setup_instr,invoke_instr,rom_b,ram_b,validate"
                .into(),
            "sort_by:invoke_instr".into(),
            "visualize:invoke_instr".into(),
        ],
        &mut report,
    )?;
    for (name, text) in &artifacts {
        std::fs::write(session.dir.join(name), text)?;
        println!("wrote {}", session.dir.join(name).display());
    }
    println!("{}", report.to_text());

    // the paper's headline: TVM wins invoke latency, TFLM wins memory
    let ok = report
        .rows
        .iter()
        .filter(|r| r["model"].render() == "resnet")
        .collect::<Vec<_>>();
    let get = |backend: &str, col: &str| -> f64 {
        ok.iter()
            .find(|r| r["backend"].render() == backend)
            .and_then(|r| r[col].as_f64())
            .unwrap_or(f64::NAN)
    };
    println!(
        "resnet: TFLM/TVM invoke ratio = {:.1}x (paper: ~6x), \
         TVM/TFLM RAM ratio = {:.1}x (paper: ~2x)",
        get("tflmi", "invoke_instr") / get("tvmaot", "invoke_instr"),
        get("tvmaot", "ram_b") / get("tflmi", "ram_b"),
    );
    Ok(())
}
