"""L2: the JAX compute graph — quantized int8 inference for a TModel.

`make_model_fn` turns a TModel into a jittable int8→int8 function whose
CONV_2D / DEPTHWISE_CONV_2D / FULLY_CONNECTED ops run through the L1
Pallas kernels (kernels/conv2d.py); the remaining ops are plain jnp.
aot.py lowers exactly this function to the HLO text the rust runtime
executes, so the golden path is Pallas-kernel-for-real, end to end.

Weights are folded in as constants at trace time: the lowered HLO takes
only the int8 input tensor. Layout (nhwc | nchw) selects the conv patch
packing, mirroring the paper's Table V layout study.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import tmodel as tm
from .kernels import conv2d as pk
from .kernels import ref

jax.config.update("jax_enable_x64", True)


def _quant_triple(m: tm.TModel, op: tm.Op):
    """(zp_in, requant multiplier, zp_out) for a conv/dense op."""
    xin = m.tensor(op.inputs[0])
    w = m.tensor(op.inputs[1])
    out = m.tensor(op.outputs[0])
    mult = float(
        np.float64(xin.scale) * np.float64(w.scale) / np.float64(out.scale)
    )
    return xin.zero_point, mult, out.zero_point


def make_model_fn(m: tm.TModel, layout: str = "nhwc", use_pallas: bool = True):
    """Build fn(input_q: int8) -> output_q: int8 for one TModel."""
    conv_fn = {
        ("nhwc", True): pk.conv2d_int8_nhwc,
        ("nchw", True): pk.conv2d_int8_nchw,
        ("nhwc", False): ref.conv2d_int8,
        ("nchw", False): ref.conv2d_int8,
    }[(layout, use_pallas)]
    dw_fn = pk.dwconv2d_int8 if use_pallas else ref.dwconv2d_int8
    dense_fn = pk.dense_int8 if use_pallas else ref.dense_int8

    def fn(x):
        vals = {m.inputs[0]: x}
        for op in m.ops:
            if op.opcode in (tm.OP_CONV_2D, tm.OP_DEPTHWISE_CONV_2D):
                zp_in, mult, zp_out = _quant_triple(m, op)
                w = jnp.asarray(m.tensor(op.inputs[1]).data)
                b = jnp.asarray(m.tensor(op.inputs[2]).data)
                f = conv_fn if op.opcode == tm.OP_CONV_2D else dw_fn
                vals[op.outputs[0]] = f(
                    vals[op.inputs[0]], w, b, zp_in, mult, zp_out,
                    stride=(op.attr("stride_h"), op.attr("stride_w")),
                    padding=op.attr("padding"),
                    act=op.attr("fused_act"),
                )
            elif op.opcode == tm.OP_FULLY_CONNECTED:
                zp_in, mult, zp_out = _quant_triple(m, op)
                w = jnp.asarray(m.tensor(op.inputs[1]).data)
                b = jnp.asarray(m.tensor(op.inputs[2]).data)
                vals[op.outputs[0]] = dense_fn(
                    vals[op.inputs[0]], w, b, zp_in, mult, zp_out,
                    act=op.attr("fused_act"),
                )
            elif op.opcode == tm.OP_AVG_POOL_2D:
                vals[op.outputs[0]] = ref.avgpool_int8(
                    vals[op.inputs[0]],
                    (op.attr("filter_h"), op.attr("filter_w")),
                    (op.attr("stride_h"), op.attr("stride_w")),
                    op.attr("padding"),
                )
            elif op.opcode == tm.OP_MAX_POOL_2D:
                vals[op.outputs[0]] = ref.maxpool_int8(
                    vals[op.inputs[0]],
                    (op.attr("filter_h"), op.attr("filter_w")),
                    (op.attr("stride_h"), op.attr("stride_w")),
                    op.attr("padding"),
                )
            elif op.opcode == tm.OP_ADD:
                ta = m.tensor(op.inputs[0])
                tb = m.tensor(op.inputs[1])
                to = m.tensor(op.outputs[0])
                vals[op.outputs[0]] = ref.add_int8(
                    vals[op.inputs[0]], vals[op.inputs[1]],
                    ta.scale, ta.zero_point, tb.scale, tb.zero_point,
                    to.scale, to.zero_point, op.attr("fused_act", 0),
                )
            elif op.opcode == tm.OP_RESHAPE:
                to = m.tensor(op.outputs[0])
                vals[op.outputs[0]] = vals[op.inputs[0]].reshape(to.shape)
            elif op.opcode == tm.OP_SOFTMAX:
                ta = m.tensor(op.inputs[0])
                vals[op.outputs[0]] = ref.softmax_int8(
                    vals[op.inputs[0]], ta.scale, ta.zero_point
                )
            else:
                raise NotImplementedError(
                    f"opcode {op.opcode} ({tm.OP_NAMES.get(op.opcode)})"
                )
        return (vals[m.outputs[0]],)

    return fn


def golden_io(m: tm.TModel, seed: int = 7, layout: str = "nhwc"):
    """Deterministic (input, output) pair for the validate feature."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=m.tensor(m.inputs[0]).shape).astype(
        np.int8
    )
    y = np.asarray(make_model_fn(m, layout=layout)(jnp.asarray(x))[0])
    return x, y
