"""Pure-jnp int8 inference oracles — the L1 correctness reference.

Every Pallas kernel in this package is checked against these functions
(exact integer equality) by python/tests/test_kernels.py, and the rust
virtual-MCU executor implements the same arithmetic (validated end-to-end
by the `validate` feature through PJRT).

Conventions (see tmodel.py):
  activations NHWC int8 · conv weights OHWI · dwconv weights 1HWC ·
  dense weights [out, in] · biases int32 · weights symmetric (zp = 0).

Requantization: float64 multiplier + round-half-even (see quant.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

jax.config.update("jax_enable_x64", True)

QMIN = -128
QMAX = 127


def same_pads(size: int, k: int, s: int) -> tuple:
    """TFLite/TF SAME padding for one spatial dim."""
    out = -(-size // s)  # ceil
    total = max((out - 1) * s + k - size, 0)
    before = total // 2
    return before, total - before


def pad_nhwc(x, kh, kw, sh, sw, padding: int, value: int):
    """Pad an NHWC tensor for SAME padding with the input zero-point."""
    if padding == 1:  # VALID
        return x
    _, h, w, _ = x.shape
    ph = same_pads(h, kh, sh)
    pw = same_pads(w, kw, sw)
    return jnp.pad(
        x, ((0, 0), ph, pw, (0, 0)), constant_values=value
    )


def requantize(acc, multiplier: float, zero_point: int, act: int = 0):
    """int32 accumulator -> int8 (round-half-even, fused-ReLU clamp)."""
    y = jnp.round(acc.astype(jnp.float64) * jnp.float64(multiplier))
    y = y + zero_point
    lo = zero_point if act == 1 else QMIN
    return jnp.clip(y, lo, QMAX).astype(jnp.int8)


def conv2d_int8(x, w, bias, zp_in, multiplier, zp_out,
                stride=(1, 1), padding=0, act=0):
    """Quantized CONV_2D. x NHWC i8, w OHWI i8, bias i32 -> NHWC i8."""
    sh, sw = stride
    oc, kh, kw, ic = w.shape
    xp = pad_nhwc(x, kh, kw, sh, sw, padding, zp_in)
    lhs = xp.astype(jnp.int32) - jnp.int32(zp_in)
    rhs = jnp.transpose(w, (1, 2, 3, 0)).astype(jnp.int32)  # HWIO
    acc = lax.conv_general_dilated(
        lhs, rhs, window_strides=(sh, sw), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )
    acc = acc + bias.astype(jnp.int32)[None, None, None, :]
    return requantize(acc, multiplier, zp_out, act)


def dwconv2d_int8(x, w, bias, zp_in, multiplier, zp_out,
                  stride=(1, 1), padding=0, act=0):
    """Quantized DEPTHWISE_CONV_2D. w is 1HWC i8."""
    sh, sw = stride
    _, kh, kw, c = w.shape
    xp = pad_nhwc(x, kh, kw, sh, sw, padding, zp_in)
    lhs = xp.astype(jnp.int32) - jnp.int32(zp_in)
    rhs = jnp.transpose(w, (1, 2, 0, 3)).astype(jnp.int32)  # [kh,kw,1,C]
    acc = lax.conv_general_dilated(
        lhs, rhs, window_strides=(sh, sw), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
        preferred_element_type=jnp.int32,
    )
    acc = acc + bias.astype(jnp.int32)[None, None, None, :]
    return requantize(acc, multiplier, zp_out, act)


def dense_int8(x, w, bias, zp_in, multiplier, zp_out, act=0):
    """Quantized FULLY_CONNECTED. x [B, in] i8, w [out, in] i8."""
    lhs = x.astype(jnp.int32) - jnp.int32(zp_in)
    acc = lhs @ w.astype(jnp.int32).T + bias.astype(jnp.int32)[None, :]
    return requantize(acc, multiplier, zp_out, act)


def avgpool_int8(x, filter_hw, stride=(1, 1), padding=1):
    """Quantized AVG_POOL_2D (scale/zp preserved, round-half-even)."""
    fh, fw = filter_hw
    sh, sw = stride
    # SAME avg-pool divides by the true window size; we only use VALID
    # (global) pooling in the zoo, so padding must be VALID here.
    assert padding == 1, "avg pool: only VALID padding is supported"
    acc = lax.reduce_window(
        x.astype(jnp.int32), 0, lax.add,
        (1, fh, fw, 1), (1, sh, sw, 1), "VALID",
    )
    y = jnp.round(acc.astype(jnp.float64) / (fh * fw))
    return jnp.clip(y, QMIN, QMAX).astype(jnp.int8)


def maxpool_int8(x, filter_hw, stride=(1, 1), padding=1):
    fh, fw = filter_hw
    sh, sw = stride
    assert padding == 1, "max pool: only VALID padding is supported"
    return lax.reduce_window(
        x, jnp.int8(QMIN), lax.max, (1, fh, fw, 1), (1, sh, sw, 1), "VALID"
    )


def add_int8(a, b, sa, zpa, sb, zpb, so, zpo, act=0):
    """Quantized ADD: rescale both operands into the output scale."""
    fa = (a.astype(jnp.float64) - zpa) * (sa / so)
    fb = (b.astype(jnp.float64) - zpb) * (sb / so)
    y = jnp.round(fa + fb) + zpo
    lo = zpo if act == 1 else QMIN
    return jnp.clip(y, lo, QMAX).astype(jnp.int8)


def softmax_int8(x, s_in, zp_in):
    """Quantized SOFTMAX with the TFLite output convention
    (scale = 1/256, zero_point = -128). Uses f32 exp; the validate
    feature allows ±1 quantum on softmax outputs (DESIGN.md §1)."""
    f = (x.astype(jnp.float32) - zp_in) * jnp.float32(s_in)
    f = f - jnp.max(f, axis=-1, keepdims=True)
    e = jnp.exp(f)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    y = jnp.round(p.astype(jnp.float64) * 256.0) - 128
    return jnp.clip(y, QMIN, QMAX).astype(jnp.int8)
