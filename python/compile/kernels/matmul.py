"""L1 Pallas kernel: blocked int8×int8→int32 matmul.

This is the compute hot-spot of every model in the zoo: CONV_2D is
lowered to im2col + matmul (exactly what CMSIS-NN/TVM do on Cortex-M) and
FULLY_CONNECTED is a [B,K]×[K,N] matmul. The kernel is tiled for VMEM via
BlockSpec — the TPU analogue of the paper's NCHWc spatial-locality layout
(DESIGN.md §Hardware-Adaptation):

  grid = (M/bm, N/bn); each program stages an int8 [bm,K] LHS block and
  an int8 [K,bn] RHS block in VMEM and issues one int8→int32 MXU matmul.

interpret=True throughout: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against ref.py and real-TPU
efficiency is estimated from the VMEM footprint in DESIGN.md §6.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: int8 blocks -> int32 accumulate."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps the grid exact
    without masking; model dims in the zoo are multiples of 8)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul_int8(x, w, bm: int = 128, bn: int = 128):
    """[M,K] i8 × [K,N] i8 -> [M,N] i32, Pallas-blocked over (M, N).

    K is kept whole per block: for the zoo's shapes (K ≤ 2.8k) an
    int8 [bm,K] + [K,bn] staging plus the int32 [bm,bn] tile is ≤ 1 MiB
    of VMEM — comfortably under the 16 MiB/core budget.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x, w)


def vmem_bytes(m: int, k: int, n: int, bm: int = 128, bn: int = 128) -> int:
    """Estimated per-program VMEM footprint of matmul_int8 (perf pass)."""
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return bm * k + k * bn + 4 * bm * bn
