"""L1 Pallas convolution kernels (int8), NHWC and NCHW variants.

CONV_2D is lowered to im2col + the Pallas matmul kernel — the same
GEMM-ification the paper's frameworks use on MCUs (CMSIS-NN, TVM's
conv2d_nhwc / conv2d_nchw schedules). Two entry points mirror the
paper's layout study (Table V):

  conv2d_int8_nhwc — patches gathered channels-last (TFLite default)
  conv2d_int8_nchw — patches gathered channels-first (TVM default);
      numerically identical, but the weight matrix is packed OIHW-io
      block-contiguous, the analogue of TVM's NCHWc transform.

The depthwise kernel operates directly on channel blocks in VMEM.
All kernels are exact-integer and are checked against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import matmul_int8
from .ref import pad_nhwc, requantize


def _im2col_nhwc(xp, kh, kw, sh, sw, oh, ow):
    """[1,Hp,Wp,C] -> [OH*OW, kh*kw*C] patch matrix (channels-last)."""
    _, hp, wp, c = xp.shape
    cols = []
    for i in range(kh):
        for j in range(kw):
            sl = jax.lax.slice(
                xp, (0, i, j, 0),
                (1, i + sh * (oh - 1) + 1, j + sw * (ow - 1) + 1, c),
                (1, sh, sw, 1),
            )
            cols.append(sl.reshape(oh * ow, c))
    return jnp.concatenate(cols, axis=1)


def _im2col_nchw(xp, kh, kw, sh, sw, oh, ow):
    """Channels-first patch matrix: [OH*OW, C*kh*kw] ordered (c, i, j)."""
    _, hp, wp, c = xp.shape
    xc = jnp.transpose(xp, (0, 3, 1, 2))  # NCHW
    # gather per (c-major, kh, kw): slice once per (i, j), then interleave
    per_ij = []
    for i in range(kh):
        for j in range(kw):
            sl = jax.lax.slice(
                xc, (0, 0, i, j),
                (1, c, i + sh * (oh - 1) + 1, j + sw * (ow - 1) + 1),
                (1, 1, sh, sw),
            )  # [1, C, OH, OW]
            per_ij.append(sl.reshape(c, oh * ow))
    # stack -> [kh*kw, C, OH*OW] -> want column order (c, i, j)
    stk = jnp.stack(per_ij, axis=0)
    stk = jnp.transpose(stk, (2, 1, 0))  # [OH*OW, C, kh*kw]
    return stk.reshape(oh * ow, c * kh * kw)


def _out_hw(h, w, kh, kw, sh, sw, padding):
    if padding == 0:  # SAME
        return -(-h // sh), -(-w // sw)
    return (h - kh) // sh + 1, (w - kw) // sw + 1


def conv2d_int8_nhwc(x, w, bias, zp_in, multiplier, zp_out,
                     stride=(1, 1), padding=0, act=0):
    """Quantized CONV_2D, NHWC im2col + Pallas matmul. w is OHWI."""
    sh, sw = stride
    oc, kh, kw, ic = w.shape
    _, h, wd, _ = x.shape
    oh, ow = _out_hw(h, wd, kh, kw, sh, sw, padding)
    xp = pad_nhwc(x, kh, kw, sh, sw, padding, zp_in)
    patches = _im2col_nhwc(xp, kh, kw, sh, sw, oh, ow)  # [M, khkwC]
    # weight matrix [khkwC, OC], rows ordered (i, j, c) to match patches
    wm = jnp.transpose(w, (1, 2, 3, 0)).reshape(kh * kw * ic, oc)
    acc = matmul_int8(patches, wm)
    # zero-point correction: acc -= zp_in * colsum(wm)
    colsum = jnp.sum(wm.astype(jnp.int32), axis=0)
    acc = acc - jnp.int32(zp_in) * colsum[None, :]
    acc = acc + bias.astype(jnp.int32)[None, :]
    y = requantize(acc, multiplier, zp_out, act)
    return y.reshape(1, oh, ow, oc)


def conv2d_int8_nchw(x, w, bias, zp_in, multiplier, zp_out,
                     stride=(1, 1), padding=0, act=0):
    """Same conv, channels-first patch/weight packing (TVM-default
    analogue). Numerically identical to the NHWC variant."""
    sh, sw = stride
    oc, kh, kw, ic = w.shape
    _, h, wd, _ = x.shape
    oh, ow = _out_hw(h, wd, kh, kw, sh, sw, padding)
    xp = pad_nhwc(x, kh, kw, sh, sw, padding, zp_in)
    patches = _im2col_nchw(xp, kh, kw, sh, sw, oh, ow)  # [M, C*khkw]
    # weight matrix rows ordered (c, i, j): OHWI -> OIHW -> [C*khkw, OC]
    wm = jnp.transpose(w, (3, 1, 2, 0)).reshape(ic * kh * kw, oc)
    acc = matmul_int8(patches, wm)
    colsum = jnp.sum(wm.astype(jnp.int32), axis=0)
    acc = acc - jnp.int32(zp_in) * colsum[None, :]
    acc = acc + bias.astype(jnp.int32)[None, :]
    y = requantize(acc, multiplier, zp_out, act)
    return y.reshape(1, oh, ow, oc)


def _dwconv_kernel(x_ref, w_ref, o_ref, *, kh, kw, sh, sw, oh, ow):
    """Depthwise conv over one VMEM channel block.

    x_ref: [Hp, Wp, cb] int8 (pre-padded; cast per-tap to keep the
    VMEM block int8). w_ref: [kh, kw, cb] int8. o_ref: int32.
    """
    xb = x_ref[...]
    acc = jnp.zeros((oh, ow, xb.shape[-1]), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            tap = jax.lax.slice(
                xb, (i, j, 0),
                (i + sh * (oh - 1) + 1, j + sw * (ow - 1) + 1, xb.shape[-1]),
                (sh, sw, 1),
            ).astype(jnp.int32)
            acc = acc + tap * w_ref[i, j, :].astype(jnp.int32)
    o_ref[...] = acc


def dwconv2d_int8(x, w, bias, zp_in, multiplier, zp_out,
                  stride=(1, 1), padding=0, act=0, cb: int = 32):
    """Quantized DEPTHWISE_CONV_2D as a channel-blocked Pallas kernel.

    w is 1HWC. The zero-point correction is folded per-channel:
    acc_c -= zp_in * sum_ij(w[i,j,c]).
    """
    sh, sw = stride
    _, kh, kw, c = w.shape
    _, h, wd, _ = x.shape
    oh, ow = _out_hw(h, wd, kh, kw, sh, sw, padding)
    xp = pad_nhwc(x, kh, kw, sh, sw, padding, zp_in)[0]  # [Hp,Wp,C]
    wk = w[0]  # [kh,kw,C]
    while c % cb != 0:
        cb -= 1
    grid = (c // cb,)
    kern = functools.partial(
        _dwconv_kernel, kh=kh, kw=kw, sh=sh, sw=sw, oh=oh, ow=ow
    )
    acc = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((xp.shape[0], xp.shape[1], cb), lambda i: (0, 0, i)),
            pl.BlockSpec((kh, kw, cb), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((oh, ow, cb), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), jnp.int32),
        interpret=True,
    )(xp, wk)
    tapsum = jnp.sum(wk.astype(jnp.int32), axis=(0, 1))  # [C]
    acc = acc - jnp.int32(zp_in) * tapsum[None, None, :]
    acc = acc + bias.astype(jnp.int32)[None, None, :]
    y = requantize(acc, multiplier, zp_out, act)
    return y.reshape(1, oh, ow, c)


def dense_int8(x, w, bias, zp_in, multiplier, zp_out, act=0):
    """Quantized FULLY_CONNECTED via the Pallas matmul. w is [out,in]."""
    wm = w.astype(jnp.int8).T  # [in, out]
    acc = matmul_int8(x, wm)
    colsum = jnp.sum(wm.astype(jnp.int32), axis=0)
    acc = acc - jnp.int32(zp_in) * colsum[None, :]
    acc = acc + bias.astype(jnp.int32)[None, :]
    return requantize(acc, multiplier, zp_out, act)
