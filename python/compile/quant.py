"""Quantization arithmetic shared between the JAX golden path and the
rust virtual-MCU executor (rust/src/tinyir/exec ops — keep in sync).

All requantization uses a float64 multiplier and round-half-to-even.
TFLite proper uses a fixed-point (int32 multiplier + shift) scheme for
FPU-less MCUs; the f64 formulation is numerically equivalent within one
ulp and — being plain IEEE-754 ops — bit-reproducible across numpy, JAX
(x64 enabled) and rust, which is what the `validate` feature needs.
The deviation is documented in DESIGN.md §1.
"""

from __future__ import annotations

import numpy as np

QMIN = -128
QMAX = 127


def round_half_even(x):
    """IEEE round-half-to-even (numpy's default np.round)."""
    return np.round(x)


def quantize(x: np.ndarray, scale: float, zero_point: int) -> np.ndarray:
    """Real-valued -> int8 with round-half-even and saturation."""
    q = np.round(np.asarray(x, dtype=np.float64) / scale) + zero_point
    return np.clip(q, QMIN, QMAX).astype(np.int8)


def dequantize(q: np.ndarray, scale: float, zero_point: int) -> np.ndarray:
    return (np.asarray(q, dtype=np.float64) - zero_point) * scale


def choose_weight_scale(w: np.ndarray) -> float:
    """Symmetric per-tensor weight scale (zero_point = 0)."""
    m = float(np.max(np.abs(w)))
    if m == 0.0:
        m = 1.0
    return m / 127.0


def choose_act_qparams(x: np.ndarray, relu: bool) -> tuple:
    """Affine activation quantization params from observed float range.

    relu outputs use the asymmetric [0, max] range (zero_point = -128),
    matching the TFLite convention for ReLU-fused ops.
    """
    if relu:
        hi = max(float(np.max(x, initial=0.0)), 1e-3)
        scale = hi / 255.0
        zp = -128
    else:
        hi = max(float(np.max(np.abs(x), initial=0.0)), 1e-3)
        scale = hi / 127.0
        zp = 0
    return scale, zp


def requantize(acc: np.ndarray, multiplier: float, zero_point: int,
               act: int = 0) -> np.ndarray:
    """int32 accumulator -> int8 output.

    out = clamp(round_he(acc * M) + zp), with a fused-ReLU lower clamp at
    the output zero point (quantized ReLU == max(q, zp_out)).
    """
    y = np.round(acc.astype(np.float64) * np.float64(multiplier)) + zero_point
    lo = zero_point if act == 1 else QMIN
    return np.clip(y, lo, QMAX).astype(np.int8)
