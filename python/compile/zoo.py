"""Model zoo: builds the four MLPerf-Tiny models of Table I as quantized
`.tmodel` files (the paper used the official TFLite flatbuffers; see
DESIGN.md §1 for the substitution).

Architectures are the canonical MLPerf-Tiny ones:

  aww    — DS-CNN (keyword spotting): conv 64×(10,4)/2 + 4×[dw 3×3 + pw
           1×1, 64ch] + global avg-pool + fc 12 + softmax
  vww    — MobileNetV1 (visual wake words), 96×96×3, width multiplier
           chosen (0.3, rounded to 8) so the quantized size lands near
           Table I's 325 kB and above toycar
  resnet — ResNet-8 (CIFAR-10 image classification)
  toycar — DCASE anomaly-detection autoencoder 640-128⁴-8-128⁴-640

Weights are deterministic (seeded per layer); activation quantization
params are calibrated by running a float forward pass on a seeded probe
batch and taking per-tensor ranges — the same post-training-quantization
recipe TFLite uses, minus the real datasets (unavailable here).
"""

from __future__ import annotations

import numpy as np

from . import quant
from .tmodel import (
    ACT_NONE, ACT_RELU, DTYPE_F32, DTYPE_I8, DTYPE_I32,
    OP_ADD, OP_AVG_POOL_2D, OP_CONV_2D, OP_DEPTHWISE_CONV_2D,
    OP_FULLY_CONNECTED, OP_RESHAPE, OP_SOFTMAX,
    PAD_SAME, PAD_VALID, Op, TModel, Tensor,
)

MODEL_NAMES = ("aww", "vww", "resnet", "toycar")

# Table I reference values (kB) for reporting/tests.
PAPER_SIZES_KB = {"aww": 58.3, "vww": 325.0, "resnet": 96.2, "toycar": 270.0}


# --------------------------------------------------------------------------
# float reference ops for calibration (numpy, NHWC)
# --------------------------------------------------------------------------

def _same_pad(x, kh, kw, sh, sw):
    from .kernels.ref import same_pads  # shared SAME arithmetic

    _, h, w, _ = x.shape
    ph = same_pads(h, kh, sh)
    pw = same_pads(w, kw, sw)
    return np.pad(x, ((0, 0), ph, pw, (0, 0)), mode="constant")


def _conv2d_f(x, w, b, stride, padding):
    sh, sw = stride
    oc, kh, kw, ic = w.shape
    xp = _same_pad(x, kh, kw, sh, sw) if padding == PAD_SAME else x
    n, hp, wp, _ = xp.shape
    oh = (hp - kh) // sh + 1
    ow = (wp - kw) // sw + 1
    cols = np.empty((oh * ow, kh * kw * ic), dtype=np.float32)
    idx = 0
    for i in range(kh):
        for j in range(kw):
            sl = xp[0, i : i + sh * (oh - 1) + 1 : sh,
                    j : j + sw * (ow - 1) + 1 : sw, :]
            cols[:, idx * ic : (idx + 1) * ic] = sl.reshape(oh * ow, ic)
            idx += 1
    wm = w.transpose(1, 2, 3, 0).reshape(kh * kw * ic, oc)
    out = cols @ wm + b[None, :]
    return out.reshape(1, oh, ow, oc)


def _dwconv2d_f(x, w, b, stride, padding):
    sh, sw = stride
    _, kh, kw, c = w.shape
    xp = _same_pad(x, kh, kw, sh, sw) if padding == PAD_SAME else x
    _, hp, wp, _ = xp.shape
    oh = (hp - kh) // sh + 1
    ow = (wp - kw) // sw + 1
    acc = np.zeros((oh, ow, c), dtype=np.float32)
    for i in range(kh):
        for j in range(kw):
            sl = xp[0, i : i + sh * (oh - 1) + 1 : sh,
                    j : j + sw * (ow - 1) + 1 : sw, :]
            acc += sl * w[0, i, j, :][None, None, :]
    return (acc + b[None, None, :]).reshape(1, oh, ow, c)


def _relu(x):
    return np.maximum(x, 0.0)


# --------------------------------------------------------------------------
# graph builder with calibration
# --------------------------------------------------------------------------

class Builder:
    """Constructs a quantized TModel while tracking a float probe
    activation for post-training-quantization calibration."""

    def __init__(self, name: str, input_shape: tuple, seed: int):
        self.m = TModel(name=name)
        self.rng = np.random.default_rng(seed)
        # probe input in [-1, 1); input tensor is int8 scale 1/64 zp 0
        probe = self.rng.uniform(-1.0, 1.0, size=input_shape).astype(
            np.float32
        )
        in_scale = 1.0 / 64.0
        tid = self.m.add_tensor(
            Tensor("input", input_shape, DTYPE_I8, in_scale, 0)
        )
        self.m.inputs = [tid]
        # keep the probe consistent with int8 representability
        q = quant.quantize(probe, in_scale, 0)
        self.probe = {tid: quant.dequantize(q, in_scale, 0).astype(np.float32)}
        self.cursor = tid  # last produced activation

    # -- helpers -----------------------------------------------------------
    def _act_tensor(self, name, shape, fval, relu):
        scale, zp = quant.choose_act_qparams(fval, relu)
        tid = self.m.add_tensor(Tensor(name, shape, DTYPE_I8, scale, zp))
        self.probe[tid] = fval
        return tid

    def _weights(self, name, shape, fanin):
        w = self.rng.normal(0.0, 1.0 / np.sqrt(fanin), size=shape).astype(
            np.float32
        )
        ws = quant.choose_weight_scale(w)
        wq = quant.quantize(w, ws, 0)
        tid = self.m.add_tensor(
            Tensor(name, shape, DTYPE_I8, ws, 0, data=wq)
        )
        # calibrate with the *quantized* weights so int8 and float paths
        # see the same effective parameters
        return tid, quant.dequantize(wq, ws, 0).astype(np.float32), ws

    def _bias(self, name, n, in_scale, w_scale):
        b = self.rng.normal(0.0, 0.05, size=(n,)).astype(np.float32)
        bs = in_scale * w_scale
        bq = np.round(b.astype(np.float64) / bs).astype(np.int64)
        bq = np.clip(bq, -(2**31), 2**31 - 1).astype(np.int32)
        tid = self.m.add_tensor(
            Tensor(name, (n,), DTYPE_I32, bs, 0, data=bq)
        )
        return tid, (bq.astype(np.float64) * bs).astype(np.float32)

    # -- layers ------------------------------------------------------------
    def conv2d(self, oc, kh, kw, stride=(1, 1), padding=PAD_SAME,
               relu=True, name=None):
        xid = self.cursor
        xin = self.m.tensor(xid)
        ic = xin.shape[-1]
        name = name or f"conv{len(self.m.ops)}"
        wid, wf, ws = self._weights(
            f"{name}.w", (oc, kh, kw, ic), kh * kw * ic
        )
        bid, bf = self._bias(f"{name}.b", oc, xin.scale, ws)
        fout = _conv2d_f(self.probe[xid], wf, bf, stride, padding)
        if relu:
            fout = _relu(fout)
        oid = self._act_tensor(f"{name}.out", fout.shape, fout, relu)
        self.m.add_op(Op(
            OP_CONV_2D, name, [xid, wid, bid], [oid],
            {"stride_h": stride[0], "stride_w": stride[1],
             "padding": padding,
             "fused_act": ACT_RELU if relu else ACT_NONE},
        ))
        self.cursor = oid
        return oid

    def dwconv2d(self, kh, kw, stride=(1, 1), padding=PAD_SAME,
                 relu=True, name=None):
        xid = self.cursor
        xin = self.m.tensor(xid)
        c = xin.shape[-1]
        name = name or f"dwconv{len(self.m.ops)}"
        wid, wf, ws = self._weights(f"{name}.w", (1, kh, kw, c), kh * kw)
        bid, bf = self._bias(f"{name}.b", c, xin.scale, ws)
        fout = _dwconv2d_f(self.probe[xid], wf, bf, stride, padding)
        if relu:
            fout = _relu(fout)
        oid = self._act_tensor(f"{name}.out", fout.shape, fout, relu)
        self.m.add_op(Op(
            OP_DEPTHWISE_CONV_2D, name, [xid, wid, bid], [oid],
            {"stride_h": stride[0], "stride_w": stride[1],
             "padding": padding,
             "fused_act": ACT_RELU if relu else ACT_NONE},
        ))
        self.cursor = oid
        return oid

    def dense(self, out_n, relu=False, name=None):
        xid = self.cursor
        xin = self.m.tensor(xid)
        in_n = xin.shape[-1]
        name = name or f"fc{len(self.m.ops)}"
        wid, wf, ws = self._weights(f"{name}.w", (out_n, in_n), in_n)
        bid, bf = self._bias(f"{name}.b", out_n, xin.scale, ws)
        fout = self.probe[xid].reshape(1, in_n) @ wf.T + bf[None, :]
        if relu:
            fout = _relu(fout)
        oid = self._act_tensor(f"{name}.out", (1, out_n), fout, relu)
        self.m.add_op(Op(
            OP_FULLY_CONNECTED, name, [xid, wid, bid], [oid],
            {"fused_act": ACT_RELU if relu else ACT_NONE},
        ))
        self.cursor = oid
        return oid

    def global_avgpool(self, name=None):
        xid = self.cursor
        xin = self.m.tensor(xid)
        _, h, w, c = xin.shape
        name = name or f"avgpool{len(self.m.ops)}"
        fout = np.mean(self.probe[xid], axis=(1, 2), keepdims=True)
        # avg-pool preserves scale/zp
        oid = self.m.add_tensor(
            Tensor(f"{name}.out", (1, 1, 1, c), DTYPE_I8,
                   xin.scale, xin.zero_point)
        )
        self.probe[oid] = fout
        self.m.add_op(Op(
            OP_AVG_POOL_2D, name, [xid], [oid],
            {"filter_h": h, "filter_w": w, "stride_h": 1, "stride_w": 1,
             "padding": PAD_VALID},
        ))
        self.cursor = oid
        return oid

    def reshape(self, shape, name=None):
        xid = self.cursor
        xin = self.m.tensor(xid)
        name = name or f"reshape{len(self.m.ops)}"
        oid = self.m.add_tensor(
            Tensor(f"{name}.out", tuple(shape), DTYPE_I8,
                   xin.scale, xin.zero_point)
        )
        self.probe[oid] = self.probe[xid].reshape(shape)
        self.m.add_op(Op(OP_RESHAPE, name, [xid], [oid], {}))
        self.cursor = oid
        return oid

    def add(self, aid, bid, relu=True, name=None):
        ta, tb = self.m.tensor(aid), self.m.tensor(bid)
        name = name or f"add{len(self.m.ops)}"
        fout = self.probe[aid] + self.probe[bid]
        if relu:
            fout = _relu(fout)
        oid = self._act_tensor(f"{name}.out", ta.shape, fout, relu)
        self.m.add_op(Op(
            OP_ADD, name, [aid, bid], [oid],
            {"fused_act": ACT_RELU if relu else ACT_NONE},
        ))
        self.cursor = oid
        return oid

    def softmax(self, name=None):
        xid = self.cursor
        xin = self.m.tensor(xid)
        name = name or f"softmax{len(self.m.ops)}"
        f = self.probe[xid].astype(np.float64)
        f = f - f.max(axis=-1, keepdims=True)
        p = np.exp(f) / np.exp(f).sum(axis=-1, keepdims=True)
        oid = self.m.add_tensor(
            Tensor(f"{name}.out", xin.shape, DTYPE_I8, 1.0 / 256.0, -128)
        )
        self.probe[oid] = p.astype(np.float32)
        self.m.add_op(Op(OP_SOFTMAX, name, [xid], [oid], {}))
        self.cursor = oid
        return oid

    def finish(self) -> TModel:
        self.m.outputs = [self.cursor]
        return self.m


# --------------------------------------------------------------------------
# the four models
# --------------------------------------------------------------------------

def build_aww(seed: int = 101) -> TModel:
    """DS-CNN keyword spotting: 49×10 MFCC input, 12 classes."""
    b = Builder("aww", (1, 49, 10, 1), seed)
    b.conv2d(64, 10, 4, stride=(2, 2))
    for _ in range(4):
        b.dwconv2d(3, 3)
        b.conv2d(64, 1, 1)
    b.global_avgpool()
    b.reshape((1, 64))
    b.dense(12)
    b.softmax()
    return b.finish()


def _scale_ch(c: int, alpha: float) -> int:
    return max(8, int(round(c * alpha / 8.0)) * 8)


def build_vww(seed: int = 202, alpha: float = 0.3) -> TModel:
    """MobileNetV1 visual wake words: 96×96×3 input, 2 classes."""
    b = Builder("vww", (1, 96, 96, 3), seed)
    b.conv2d(_scale_ch(32, alpha), 3, 3, stride=(2, 2))
    cfg = [  # (stride, base output channels)
        (1, 64), (2, 128), (1, 128), (2, 256), (1, 256),
        (2, 512), (1, 512), (1, 512), (1, 512), (1, 512), (1, 512),
        (2, 1024), (1, 1024),
    ]
    for s, oc in cfg:
        b.dwconv2d(3, 3, stride=(s, s))
        b.conv2d(_scale_ch(oc, alpha), 1, 1)
    b.global_avgpool()
    b.reshape((1, _scale_ch(1024, alpha)))
    b.dense(2)
    b.softmax()
    return b.finish()


def build_resnet(seed: int = 303) -> TModel:
    """ResNet-8 image classification: 32×32×3 CIFAR input, 10 classes."""
    b = Builder("resnet", (1, 32, 32, 3), seed)
    b.conv2d(16, 3, 3)
    ch_in = 16
    for ch, stride in ((16, 1), (32, 2), (64, 2)):
        skip = b.cursor
        y = b.conv2d(ch, 3, 3, stride=(stride, stride))
        y = b.conv2d(ch, 3, 3, relu=False)
        if stride != 1 or ch != ch_in:
            b.cursor = skip
            skip = b.conv2d(ch, 1, 1, stride=(stride, stride), relu=False)
        b.add(y, skip, relu=True)
        ch_in = ch
    b.global_avgpool()
    b.reshape((1, 64))
    b.dense(10)
    b.softmax()
    return b.finish()


def build_toycar(seed: int = 404) -> TModel:
    """DCASE toy-car anomaly-detection autoencoder: 640-d input."""
    b = Builder("toycar", (1, 640), seed)
    for _ in range(4):
        b.dense(128, relu=True)
    b.dense(8, relu=True)
    for _ in range(4):
        b.dense(128, relu=True)
    b.dense(640, relu=False)
    return b.finish()


BUILDERS = {
    "aww": build_aww,
    "vww": build_vww,
    "resnet": build_resnet,
    "toycar": build_toycar,
}


def build(name: str) -> TModel:
    return BUILDERS[name]()


def build_all(out_dir) -> dict:
    """Build every model, save .tmodel files, return {name: TModel}."""
    import os

    os.makedirs(out_dir, exist_ok=True)
    models = {}
    for name in MODEL_NAMES:
        m = build(name)
        m.save(os.path.join(out_dir, f"{name}.tmodel"))
        models[name] = m
    return models


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts/models"
    for name, m in build_all(out).items():
        print(
            f"{name:8s} params={m.param_count():>8d} "
            f"weights={m.weight_bytes() / 1024:7.1f} kB "
            f"(paper {PAPER_SIZES_KB[name]} kB) macs={m.macs() / 1e6:6.2f} M"
        )
