"""AOT bridge: lower every zoo model's JAX function (which runs the L1
Pallas kernels) to HLO **text** and dump golden I/O vectors.

HLO text — not `lowered.compiler_ir("hlo").serialize()` — is the
interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids that the rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (all under artifacts/, gitignored, rebuilt by `make artifacts`):
    models/<name>.tmodel       — quantized model (zoo.py)
    <name>.hlo.txt             — golden int8 inference, input -> (output,)
    golden/<name>.json         — deterministic input/output vectors

Python runs ONCE here; the rust coordinator never imports it.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from . import zoo

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe path).

    print_large_constants=True is load-bearing: the default printer
    elides big weight arrays as `constant({...})`, which the rust
    side's HLO text parser silently accepts as uninitialized data —
    the model would "run" with garbage weights.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(m, layout: str = "nhwc", use_pallas: bool = False) -> str:
    """Lower one model to HLO text.

    The *exported* golden path uses the pure-jnp reference kernels
    (`use_pallas=False`): the rust side's xla_extension 0.5.1 runtime
    miscompiles the `while`-loop programs that Pallas interpret-mode
    grids lower to (outputs come back with corrupted element striding
    for both s8 and s32 tuples). The Pallas kernels are the same
    function — python/tests/test_models.py::test_pallas_path_matches_
    ref_path proves bit-equality on whole models, and test_kernels.py
    sweeps them against ref.py with hypothesis — so the exported HLO
    is the L1 kernels' semantics, lowered via the runtime-compatible
    path. (On a real TPU PJRT plugin, `use_pallas=True` exports the
    Mosaic kernels directly.)
    """
    fn = model_mod.make_model_fn(m, layout=layout, use_pallas=use_pallas)
    spec = jax.ShapeDtypeStruct(m.tensor(m.inputs[0]).shape, jnp.int8)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts",
                   help="artifacts directory")
    p.add_argument("--models", nargs="*", default=list(zoo.MODEL_NAMES))
    p.add_argument("--skip-golden", action="store_true")
    args = p.parse_args()

    out = args.out
    os.makedirs(os.path.join(out, "models"), exist_ok=True)
    os.makedirs(os.path.join(out, "golden"), exist_ok=True)

    for name in args.models:
        m = zoo.build(name)
        m.save(os.path.join(out, "models", f"{name}.tmodel"))
        hlo = lower_model(m)
        hlo_path = os.path.join(out, f"{name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(hlo)
        print(f"{name}: wrote {len(hlo)} chars of HLO -> {hlo_path}")
        if not args.skip_golden:
            x, y = model_mod.golden_io(m)
            gpath = os.path.join(out, "golden", f"{name}.json")
            with open(gpath, "w") as f:
                json.dump(
                    {
                        "model": name,
                        "input_shape": list(x.shape),
                        "input": x.flatten().tolist(),
                        "output_shape": list(y.shape),
                        "output": y.flatten().tolist(),
                    },
                    f,
                )
            print(f"{name}: golden {x.shape} -> {y.shape} ({gpath})")


if __name__ == "__main__":
    main()
