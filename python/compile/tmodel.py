"""TModel — the binary model interchange format between the python
build path (zoo.py writes models) and the rust coordinator (frontends/
reads them).

This substitutes for the TFLite flatbuffer format used by the paper: a
flat, versioned, little-endian container holding quantized tensors and a
topologically-ordered op list.

Layout (all integers little-endian):

    magic   4 bytes  b"TMDL"
    version u32      (currently 1)
    name    str      (u32 length + utf-8 bytes)
    n_tensors u32
    n_ops     u32
    n_inputs  u32, then u32 tensor-ids
    n_outputs u32, then u32 tensor-ids
    tensors:
        name     str
        dtype    u8   (0=i8, 1=i16, 2=i32, 3=f32)
        ndim     u8, dims u32 * ndim
        scale    f32
        zero_pt  i32
        has_data u8; if 1: data_len u64 + raw bytes (row-major)
    ops:
        opcode   u8
        name     str
        n_in     u8, u32 tensor-ids
        n_out    u8, u32 tensor-ids
        n_attrs  u8, each: key str(u8 len), value i64

Opcode registry (shared with rust/src/graph/op.rs — keep in sync):

    0 CONV_2D             attrs: stride_h, stride_w, padding(0=same,1=valid), fused_act(0=none,1=relu)
    1 DEPTHWISE_CONV_2D   attrs: stride_h, stride_w, padding, fused_act
    2 FULLY_CONNECTED     attrs: fused_act
    3 AVG_POOL_2D         attrs: filter_h, filter_w, stride_h, stride_w, padding
    4 MAX_POOL_2D         attrs: filter_h, filter_w, stride_h, stride_w, padding
    5 ADD                 attrs: fused_act
    6 RESHAPE             attrs: (target shape comes from output tensor)
    7 SOFTMAX             attrs: -

Tensor layout conventions (TFLite-style):
    CONV_2D weights:            OHWI  [out_c, kh, kw, in_c]
    DEPTHWISE_CONV_2D weights:  1HWC  [1, kh, kw, channels]
    FULLY_CONNECTED weights:    [out, in]
    activations:                NHWC  [n, h, w, c]
    biases: int32, scale = in_scale * w_scale, zero_pt = 0
"""

from __future__ import annotations

import io
import struct
from dataclasses import dataclass, field

import numpy as np

MAGIC = b"TMDL"
VERSION = 1

DTYPE_I8 = 0
DTYPE_I16 = 1
DTYPE_I32 = 2
DTYPE_F32 = 3

_DTYPE_TO_NP = {
    DTYPE_I8: np.int8,
    DTYPE_I16: np.int16,
    DTYPE_I32: np.int32,
    DTYPE_F32: np.float32,
}
_NP_TO_DTYPE = {np.dtype(v): k for k, v in _DTYPE_TO_NP.items()}

OP_CONV_2D = 0
OP_DEPTHWISE_CONV_2D = 1
OP_FULLY_CONNECTED = 2
OP_AVG_POOL_2D = 3
OP_MAX_POOL_2D = 4
OP_ADD = 5
OP_RESHAPE = 6
OP_SOFTMAX = 7

OP_NAMES = {
    OP_CONV_2D: "CONV_2D",
    OP_DEPTHWISE_CONV_2D: "DEPTHWISE_CONV_2D",
    OP_FULLY_CONNECTED: "FULLY_CONNECTED",
    OP_AVG_POOL_2D: "AVG_POOL_2D",
    OP_MAX_POOL_2D: "MAX_POOL_2D",
    OP_ADD: "ADD",
    OP_RESHAPE: "RESHAPE",
    OP_SOFTMAX: "SOFTMAX",
}

PAD_SAME = 0
PAD_VALID = 1

ACT_NONE = 0
ACT_RELU = 1


@dataclass
class Tensor:
    """A named tensor: quantization params plus optional constant data."""

    name: str
    shape: tuple
    dtype: int = DTYPE_I8
    scale: float = 1.0
    zero_point: int = 0
    data: np.ndarray | None = None  # None for activations

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * np.dtype(_DTYPE_TO_NP[self.dtype]).itemsize

    def np_dtype(self):
        return _DTYPE_TO_NP[self.dtype]


@dataclass
class Op:
    """One graph operation over tensor ids, with integer attributes."""

    opcode: int
    name: str
    inputs: list
    outputs: list
    attrs: dict = field(default_factory=dict)

    def attr(self, key: str, default: int | None = None) -> int:
        if key in self.attrs:
            return self.attrs[key]
        if default is None:
            raise KeyError(f"op {self.name}: missing attr {key}")
        return default


@dataclass
class TModel:
    """An in-memory model: tensors + topologically ordered ops."""

    name: str
    tensors: list = field(default_factory=list)
    ops: list = field(default_factory=list)
    inputs: list = field(default_factory=list)
    outputs: list = field(default_factory=list)

    def add_tensor(self, t: Tensor) -> int:
        self.tensors.append(t)
        return len(self.tensors) - 1

    def add_op(self, op: Op) -> int:
        self.ops.append(op)
        return len(self.ops) - 1

    def tensor(self, tid: int) -> Tensor:
        return self.tensors[tid]

    # -- size accounting (Table I reproduction) ---------------------------
    def weight_bytes(self) -> int:
        """Total bytes of constant tensor data (the 'quantized size')."""
        return sum(t.nbytes for t in self.tensors if t.data is not None)

    def param_count(self) -> int:
        return sum(
            int(np.prod(t.shape)) for t in self.tensors if t.data is not None
        )

    def macs(self) -> int:
        """Multiply-accumulate count of one inference (conv/dw/fc only)."""
        total = 0
        for op in self.ops:
            if op.opcode == OP_CONV_2D:
                w = self.tensor(op.inputs[1])
                out = self.tensor(op.outputs[0])
                oc, kh, kw, ic = w.shape
                _, oh, ow, _ = out.shape
                total += oh * ow * oc * kh * kw * ic
            elif op.opcode == OP_DEPTHWISE_CONV_2D:
                w = self.tensor(op.inputs[1])
                out = self.tensor(op.outputs[0])
                _, kh, kw, c = w.shape
                _, oh, ow, _ = out.shape
                total += oh * ow * c * kh * kw
            elif op.opcode == OP_FULLY_CONNECTED:
                w = self.tensor(op.inputs[1])
                total += int(np.prod(w.shape))
        return total

    # -- serialization ----------------------------------------------------
    def to_bytes(self) -> bytes:
        buf = io.BytesIO()
        w = buf.write
        w(MAGIC)
        w(struct.pack("<I", VERSION))
        _wstr(buf, self.name)
        w(struct.pack("<II", len(self.tensors), len(self.ops)))
        w(struct.pack("<I", len(self.inputs)))
        for tid in self.inputs:
            w(struct.pack("<I", tid))
        w(struct.pack("<I", len(self.outputs)))
        for tid in self.outputs:
            w(struct.pack("<I", tid))
        for t in self.tensors:
            _wstr(buf, t.name)
            w(struct.pack("<BB", t.dtype, len(t.shape)))
            for d in t.shape:
                w(struct.pack("<I", d))
            w(struct.pack("<fi", t.scale, t.zero_point))
            if t.data is None:
                w(struct.pack("<B", 0))
            else:
                raw = np.ascontiguousarray(
                    t.data.astype(t.np_dtype())
                ).tobytes()
                w(struct.pack("<B", 1))
                w(struct.pack("<Q", len(raw)))
                w(raw)
        for op in self.ops:
            w(struct.pack("<B", op.opcode))
            _wstr(buf, op.name)
            w(struct.pack("<B", len(op.inputs)))
            for tid in op.inputs:
                w(struct.pack("<I", tid))
            w(struct.pack("<B", len(op.outputs)))
            for tid in op.outputs:
                w(struct.pack("<I", tid))
            w(struct.pack("<B", len(op.attrs)))
            for k, v in sorted(op.attrs.items()):
                kb = k.encode()
                w(struct.pack("<B", len(kb)))
                w(kb)
                w(struct.pack("<q", v))
        return buf.getvalue()

    def save(self, path) -> None:
        with open(path, "wb") as f:
            f.write(self.to_bytes())

    @staticmethod
    def from_bytes(raw: bytes) -> "TModel":
        buf = io.BytesIO(raw)
        if buf.read(4) != MAGIC:
            raise ValueError("bad magic; not a TModel file")
        (version,) = struct.unpack("<I", buf.read(4))
        if version != VERSION:
            raise ValueError(f"unsupported TModel version {version}")
        name = _rstr(buf)
        n_tensors, n_ops = struct.unpack("<II", buf.read(8))
        (n_in,) = struct.unpack("<I", buf.read(4))
        inputs = [struct.unpack("<I", buf.read(4))[0] for _ in range(n_in)]
        (n_out,) = struct.unpack("<I", buf.read(4))
        outputs = [struct.unpack("<I", buf.read(4))[0] for _ in range(n_out)]
        m = TModel(name=name, inputs=inputs, outputs=outputs)
        for _ in range(n_tensors):
            tname = _rstr(buf)
            dtype, ndim = struct.unpack("<BB", buf.read(2))
            shape = tuple(
                struct.unpack("<I", buf.read(4))[0] for _ in range(ndim)
            )
            scale, zp = struct.unpack("<fi", buf.read(8))
            (has_data,) = struct.unpack("<B", buf.read(1))
            data = None
            if has_data:
                (dlen,) = struct.unpack("<Q", buf.read(8))
                data = np.frombuffer(
                    buf.read(dlen), dtype=_DTYPE_TO_NP[dtype]
                ).reshape(shape)
            m.tensors.append(
                Tensor(tname, shape, dtype, scale, zp, data)
            )
        for _ in range(n_ops):
            (opcode,) = struct.unpack("<B", buf.read(1))
            oname = _rstr(buf)
            (ni,) = struct.unpack("<B", buf.read(1))
            op_in = [struct.unpack("<I", buf.read(4))[0] for _ in range(ni)]
            (no,) = struct.unpack("<B", buf.read(1))
            op_out = [struct.unpack("<I", buf.read(4))[0] for _ in range(no)]
            (na,) = struct.unpack("<B", buf.read(1))
            attrs = {}
            for _ in range(na):
                (klen,) = struct.unpack("<B", buf.read(1))
                key = buf.read(klen).decode()
                (val,) = struct.unpack("<q", buf.read(8))
                attrs[key] = val
            m.ops.append(Op(opcode, oname, op_in, op_out, attrs))
        return m

    @staticmethod
    def load(path) -> "TModel":
        with open(path, "rb") as f:
            return TModel.from_bytes(f.read())


def _wstr(buf, s: str) -> None:
    b = s.encode()
    buf.write(struct.pack("<I", len(b)))
    buf.write(b)


def _rstr(buf) -> str:
    (n,) = struct.unpack("<I", buf.read(4))
    return buf.read(n).decode()
