"""L2 model zoo: Table I reproduction, determinism, and end-to-end
int8 inference through the Pallas-kernel path (small models; vww is
covered by test_aot's lowering check and the rust e2e)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile import zoo
from compile import tmodel as tm


@pytest.fixture(scope="module")
def models():
    return {name: zoo.build(name) for name in zoo.MODEL_NAMES}


def test_table1_size_ordering(models):
    """Paper Table I: aww < resnet < toycar < vww (quantized size)."""
    kb = {n: m.weight_bytes() / 1024 for n, m in models.items()}
    assert kb["aww"] < kb["resnet"] < kb["toycar"] < kb["vww"]


def test_table1_sizes_near_paper(models):
    """Within a factor of the paper's flatbuffer sizes (our container
    has no flatbuffer overhead; DESIGN.md documents the deltas)."""
    for name, m in models.items():
        kb = m.weight_bytes() / 1024
        paper = zoo.PAPER_SIZES_KB[name]
        assert 0.3 * paper < kb < 1.3 * paper, (name, kb, paper)


def test_macs_ratios_match_table4_shape(models):
    """Invoke-instruction ratios in Table IV are MAC-driven: the model
    complexity order must be resnet > vww > aww > toycar."""
    macs = {n: m.macs() for n, m in models.items()}
    assert macs["resnet"] > macs["vww"] > macs["aww"] > macs["toycar"]
    # paper: aww/resnet invoke ratio ~ 0.26, toycar/resnet ~ 0.021
    assert 0.1 < macs["aww"] / macs["resnet"] < 0.4
    assert macs["toycar"] / macs["resnet"] < 0.05


def test_zoo_is_deterministic():
    a = zoo.build("aww").to_bytes()
    b = zoo.build("aww").to_bytes()
    assert a == b


def test_all_models_have_io_and_valid_ops(models):
    for name, m in models.items():
        assert len(m.inputs) == 1 and len(m.outputs) == 1
        for op in m.ops:
            for tid in op.inputs + op.outputs:
                assert 0 <= tid < len(m.tensors), (name, op.name)
        # ops are topologically ordered: every op input is either a
        # constant or produced by an earlier op / the graph input
        produced = set(m.inputs)
        for op in m.ops:
            for tid in op.inputs:
                t = m.tensors[tid]
                assert t.data is not None or tid in produced, \
                    (name, op.name, t.name)
            produced.update(op.outputs)


def test_weights_not_degenerate(models):
    """Calibration should keep quantized values spread, not saturated."""
    for name, m in models.items():
        for t in m.tensors:
            if t.data is not None and t.dtype == tm.DTYPE_I8:
                frac_sat = float(np.mean(np.abs(t.data.astype(np.int32))
                                         == 127))
                assert frac_sat < 0.2, (name, t.name, frac_sat)
                assert t.data.std() > 1.0, (name, t.name)


@pytest.mark.parametrize("name", ["toycar", "aww"])
def test_model_fn_runs_and_is_deterministic(name, models):
    m = models[name]
    x, y = model_mod.golden_io(m, seed=7)
    x2, y2 = model_mod.golden_io(m, seed=7)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)
    assert y.dtype == np.int8


@pytest.mark.parametrize("name", ["toycar", "aww", "resnet"])
def test_pallas_path_matches_ref_path(name, models):
    """The whole L2 graph through Pallas kernels == through ref.py."""
    m = models[name]
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(
        -128, 128, m.tensor(m.inputs[0]).shape).astype(np.int8))
    y_pallas = np.asarray(model_mod.make_model_fn(m, use_pallas=True)(x)[0])
    y_ref = np.asarray(model_mod.make_model_fn(m, use_pallas=False)(x)[0])
    np.testing.assert_array_equal(y_pallas, y_ref)


def test_nchw_layout_same_numerics(models):
    """Layouts change performance (Table V), never results."""
    m = models["aww"]
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.integers(
        -128, 128, m.tensor(m.inputs[0]).shape).astype(np.int8))
    y1 = np.asarray(model_mod.make_model_fn(m, layout="nhwc")(x)[0])
    y2 = np.asarray(model_mod.make_model_fn(m, layout="nchw")(x)[0])
    np.testing.assert_array_equal(y1, y2)


def test_softmax_outputs_have_softmax_qparams(models):
    for name in ("aww", "vww", "resnet"):
        m = models[name]
        out = m.tensor(m.outputs[0])
        assert out.scale == pytest.approx(1.0 / 256.0)
        assert out.zero_point == -128
