"""TModel container format: round-trip and integrity properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tmodel as tm


def tiny_model(seed=0):
    rng = np.random.default_rng(seed)
    m = tm.TModel(name="tiny")
    x = m.add_tensor(tm.Tensor("input", (1, 4, 4, 2), tm.DTYPE_I8, 0.5, 3))
    w = m.add_tensor(tm.Tensor(
        "w", (3, 3, 3, 2), tm.DTYPE_I8, 0.01, 0,
        data=rng.integers(-128, 128, (3, 3, 3, 2)).astype(np.int8)))
    b = m.add_tensor(tm.Tensor(
        "b", (3,), tm.DTYPE_I32, 0.005, 0,
        data=rng.integers(-1000, 1000, (3,)).astype(np.int32)))
    y = m.add_tensor(tm.Tensor("y", (1, 4, 4, 3), tm.DTYPE_I8, 0.25, -1))
    m.add_op(tm.Op(tm.OP_CONV_2D, "conv0", [x, w, b], [y],
                   {"stride_h": 1, "stride_w": 1, "padding": 0,
                    "fused_act": 1}))
    m.inputs, m.outputs = [x], [y]
    return m


def test_roundtrip_preserves_everything():
    m = tiny_model()
    m2 = tm.TModel.from_bytes(m.to_bytes())
    assert m2.name == m.name
    assert m2.inputs == m.inputs and m2.outputs == m.outputs
    assert len(m2.tensors) == len(m.tensors)
    for a, b in zip(m.tensors, m2.tensors):
        assert a.name == b.name and a.shape == tuple(b.shape)
        assert a.dtype == b.dtype
        assert a.scale == pytest.approx(b.scale)
        assert a.zero_point == b.zero_point
        if a.data is None:
            assert b.data is None
        else:
            np.testing.assert_array_equal(a.data, b.data)
    for a, b in zip(m.ops, m2.ops):
        assert (a.opcode, a.name, a.inputs, a.outputs, a.attrs) == \
               (b.opcode, b.name, b.inputs, b.outputs, b.attrs)


def test_bad_magic_rejected():
    with pytest.raises(ValueError, match="magic"):
        tm.TModel.from_bytes(b"NOPE" + b"\x00" * 64)


def test_bad_version_rejected():
    raw = bytearray(tiny_model().to_bytes())
    raw[4] = 99
    with pytest.raises(ValueError, match="version"):
        tm.TModel.from_bytes(bytes(raw))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_roundtrip_is_byte_stable(seed):
    """serialize(parse(serialize(m))) == serialize(m) — reproducibility."""
    m = tiny_model(seed)
    b1 = m.to_bytes()
    b2 = tm.TModel.from_bytes(b1).to_bytes()
    assert b1 == b2


def test_size_accounting():
    m = tiny_model()
    assert m.param_count() == 3 * 3 * 3 * 2 + 3
    assert m.weight_bytes() == 54 + 12
    assert m.macs() == 4 * 4 * 3 * 3 * 3 * 2
