"""AOT bridge: HLO-text lowering sanity (the rust side integration-tests
actual PJRT execution of these artifacts)."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as model_mod, zoo


@pytest.fixture(scope="module")
def toycar():
    return zoo.build("toycar")


def test_lowering_produces_hlo_text(toycar):
    hlo = aot.lower_model(toycar)
    assert "HloModule" in hlo
    # entry computation takes exactly the int8 input tensor
    assert "s8[1,640]" in hlo
    # weights are folded: no f64/f32 parameters
    assert hlo.count("parameter(") >= 1


def test_lowering_is_deterministic(toycar):
    assert aot.lower_model(toycar) == aot.lower_model(toycar)


def test_golden_dump_roundtrip(tmp_path, toycar):
    x, y = model_mod.golden_io(toycar)
    path = tmp_path / "g.json"
    with open(path, "w") as f:
        json.dump({"input": x.flatten().tolist(),
                   "output": y.flatten().tolist()}, f)
    g = json.load(open(path))
    np.testing.assert_array_equal(
        np.array(g["input"], np.int8), x.flatten())
    np.testing.assert_array_equal(
        np.array(g["output"], np.int8), y.flatten())


def test_main_writes_artifacts(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out", str(tmp_path), "--models", "toycar"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    assert (tmp_path / "models" / "toycar.tmodel").exists()
    assert (tmp_path / "toycar.hlo.txt").exists()
    assert (tmp_path / "golden" / "toycar.json").exists()
