"""Pytest wiring for the reference suite.

* Puts ``python/`` on ``sys.path`` so ``from compile import ...``
  resolves when pytest is invoked from the repository root (the CI
  entry point is ``python -m pytest python/tests -q``).
* Skips the property-based modules when ``hypothesis`` is not
  installed (minimal environments); CI installs it, so the full suite
  always runs there.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += [
        "test_kernels.py",
        "test_quant.py",
        "test_tmodel.py",
    ]
