"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes, strides, paddings, quantization params and
data; all comparisons are EXACT integer equality (the kernels implement
identical arithmetic, so any mismatch is a bug, not noise).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as pk
from compile.kernels import ref
from compile.kernels.matmul import matmul_int8, vmem_bytes

SETTINGS = dict(max_examples=25, deadline=None)


def rng_for(data):
    return np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))


# ---------------------------------------------------------------- matmul --
@settings(**SETTINGS)
@given(data=st.data())
def test_matmul_int8_matches_numpy(data):
    m = data.draw(st.integers(1, 96), label="m")
    k = data.draw(st.integers(1, 64), label="k")
    n = data.draw(st.integers(1, 48), label="n")
    rng = rng_for(data)
    x = rng.integers(-128, 128, (m, k), dtype=np.int8)
    w = rng.integers(-128, 128, (k, n), dtype=np.int8)
    got = np.asarray(matmul_int8(jnp.asarray(x), jnp.asarray(w)))
    want = x.astype(np.int32) @ w.astype(np.int32)
    np.testing.assert_array_equal(got, want)


@settings(**SETTINGS)
@given(data=st.data())
def test_matmul_int8_blocked_matches_unblocked(data):
    """Block-size choice must not change results (pure tiling)."""
    m = data.draw(st.sampled_from([8, 32, 64, 128]))
    n = data.draw(st.sampled_from([8, 16, 64]))
    k = data.draw(st.integers(1, 40))
    bm = data.draw(st.sampled_from([8, 16, 128]))
    bn = data.draw(st.sampled_from([8, 16, 128]))
    rng = rng_for(data)
    x = rng.integers(-128, 128, (m, k), dtype=np.int8)
    w = rng.integers(-128, 128, (k, n), dtype=np.int8)
    a = np.asarray(matmul_int8(jnp.asarray(x), jnp.asarray(w), bm=bm, bn=bn))
    b = x.astype(np.int32) @ w.astype(np.int32)
    np.testing.assert_array_equal(a, b)


def test_matmul_vmem_budget():
    """Perf-pass invariant: worst-case zoo block fits in a VMEM budget."""
    # largest K in the zoo: vww pw 304->304 at 3x3 spatial => K=304
    # largest matmul: resnet stack1 conv: M=1024, K=144, N=16..64
    assert vmem_bytes(2304, 288, 64) < 4 * 1024 * 1024
    assert vmem_bytes(1024, 576, 64) < 4 * 1024 * 1024


# ------------------------------------------------------------------ conv --
def _conv_case(data, max_hw=14, max_c=8, max_oc=8):
    rng = rng_for(data)
    h = data.draw(st.integers(3, max_hw), label="h")
    w = data.draw(st.integers(3, max_hw), label="w")
    ic = data.draw(st.integers(1, max_c), label="ic")
    oc = data.draw(st.integers(1, max_oc), label="oc")
    kh = data.draw(st.integers(1, min(3, h)), label="kh")
    kw = data.draw(st.integers(1, min(3, w)), label="kw")
    sh = data.draw(st.integers(1, 2), label="sh")
    sw = data.draw(st.integers(1, 2), label="sw")
    padding = data.draw(st.integers(0, 1), label="padding")
    act = data.draw(st.integers(0, 1), label="act")
    zp_in = data.draw(st.integers(-10, 10), label="zp_in")
    zp_out = data.draw(st.integers(-20, 20), label="zp_out")
    mult = data.draw(
        st.floats(1e-4, 0.05, allow_nan=False), label="mult"
    )
    x = rng.integers(-128, 128, (1, h, w, ic), dtype=np.int8)
    wt = rng.integers(-128, 128, (oc, kh, kw, ic), dtype=np.int8)
    b = rng.integers(-(2**15), 2**15, (oc,), dtype=np.int32)
    return x, wt, b, zp_in, mult, zp_out, (sh, sw), padding, act


@settings(**SETTINGS)
@given(data=st.data())
def test_conv2d_nhwc_matches_ref(data):
    x, w, b, zp, mult, zpo, stride, pad, act = _conv_case(data)
    got = np.asarray(pk.conv2d_int8_nhwc(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
        zp, mult, zpo, stride, pad, act))
    want = np.asarray(ref.conv2d_int8(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
        zp, mult, zpo, stride, pad, act))
    np.testing.assert_array_equal(got, want)


@settings(**SETTINGS)
@given(data=st.data())
def test_conv2d_nchw_matches_ref(data):
    """The NCHW-packed variant must be numerically identical — layouts
    change performance (Table V), never results."""
    x, w, b, zp, mult, zpo, stride, pad, act = _conv_case(data)
    got = np.asarray(pk.conv2d_int8_nchw(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
        zp, mult, zpo, stride, pad, act))
    want = np.asarray(ref.conv2d_int8(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
        zp, mult, zpo, stride, pad, act))
    np.testing.assert_array_equal(got, want)


@settings(**SETTINGS)
@given(data=st.data())
def test_dwconv2d_matches_ref(data):
    rng = rng_for(data)
    h = data.draw(st.integers(3, 12))
    w = data.draw(st.integers(3, 12))
    c = data.draw(st.sampled_from([1, 2, 3, 8, 16]))
    kh = data.draw(st.integers(1, 3))
    kw = data.draw(st.integers(1, 3))
    s = data.draw(st.integers(1, 2))
    padding = data.draw(st.integers(0, 1))
    act = data.draw(st.integers(0, 1))
    zp = data.draw(st.integers(-10, 10))
    zpo = data.draw(st.integers(-20, 20))
    mult = data.draw(st.floats(1e-4, 0.05, allow_nan=False))
    x = rng.integers(-128, 128, (1, h, w, c), dtype=np.int8)
    wt = rng.integers(-128, 128, (1, kh, kw, c), dtype=np.int8)
    b = rng.integers(-(2**15), 2**15, (c,), dtype=np.int32)
    got = np.asarray(pk.dwconv2d_int8(
        jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b),
        zp, mult, zpo, (s, s), padding, act))
    want = np.asarray(ref.dwconv2d_int8(
        jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b),
        zp, mult, zpo, (s, s), padding, act))
    np.testing.assert_array_equal(got, want)


@settings(**SETTINGS)
@given(data=st.data())
def test_dense_matches_ref(data):
    rng = rng_for(data)
    b_ = data.draw(st.integers(1, 4))
    i = data.draw(st.integers(1, 64))
    o = data.draw(st.integers(1, 32))
    act = data.draw(st.integers(0, 1))
    zp = data.draw(st.integers(-10, 10))
    zpo = data.draw(st.integers(-20, 20))
    mult = data.draw(st.floats(1e-4, 0.05, allow_nan=False))
    x = rng.integers(-128, 128, (b_, i), dtype=np.int8)
    wt = rng.integers(-128, 128, (o, i), dtype=np.int8)
    bias = rng.integers(-(2**15), 2**15, (o,), dtype=np.int32)
    got = np.asarray(pk.dense_int8(
        jnp.asarray(x), jnp.asarray(wt), jnp.asarray(bias),
        zp, mult, zpo, act))
    want = np.asarray(ref.dense_int8(
        jnp.asarray(x), jnp.asarray(wt), jnp.asarray(bias),
        zp, mult, zpo, act))
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------- misc ops --
def test_same_pads_matches_tf_convention():
    assert ref.same_pads(10, 3, 1) == (1, 1)
    assert ref.same_pads(10, 4, 2) == (1, 1)
    assert ref.same_pads(49, 10, 2) == (4, 5)
    assert ref.same_pads(5, 1, 1) == (0, 0)


@settings(**SETTINGS)
@given(data=st.data())
def test_requantize_saturates_and_rounds_half_even(data):
    acc = data.draw(st.integers(-(2**30), 2**30))
    zp = data.draw(st.integers(-128, 127))
    mult = data.draw(st.floats(1e-8, 1.0, allow_nan=False))
    y = int(np.asarray(ref.requantize(jnp.asarray([acc], jnp.int32),
                                      mult, zp))[0])
    assert -128 <= y <= 127
    exact = np.round(np.float64(acc) * np.float64(mult)) + zp
    assert y == int(np.clip(exact, -128, 127))


def test_requantize_relu_clamps_at_zero_point():
    acc = jnp.asarray([-1000, -1, 0, 1, 1000], jnp.int32)
    y = np.asarray(ref.requantize(acc, 0.5, 3, act=1))
    assert (y >= 3).all()


def test_softmax_int8_is_distribution_like():
    x = jnp.asarray([[10, 20, 30, 40]], jnp.int8)
    y = np.asarray(ref.softmax_int8(x, 0.2, 0))
    # quantized probabilities: sum of (q+128)/256 ~= 1
    total = (y.astype(np.int32) + 128).sum() / 256.0
    assert abs(total - 1.0) < 0.05
    assert y.argmax() == 3
