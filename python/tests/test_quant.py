"""Quantization helper properties (quant.py)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant


@settings(max_examples=50, deadline=None)
@given(st.floats(-1e4, 1e4, allow_nan=False),
       st.floats(1e-4, 10.0, allow_nan=False),
       st.integers(-128, 127))
def test_quantize_in_range(x, scale, zp):
    q = quant.quantize(np.float32(x), scale, zp)
    assert -128 <= int(q) <= 127


@settings(max_examples=50, deadline=None)
@given(st.integers(-100, 100), st.floats(0.01, 1.0, allow_nan=False))
def test_quant_dequant_roundtrip_error_bounded(qv, scale):
    """dequantize∘quantize error is at most scale/2 for in-range values."""
    f = quant.dequantize(np.int8(qv), scale, 0)
    q2 = quant.quantize(f, scale, 0)
    assert int(q2) == qv


def test_round_half_even():
    got = quant.round_half_even(np.array([0.5, 1.5, 2.5, -0.5, -1.5]))
    np.testing.assert_array_equal(got, [0.0, 2.0, 2.0, -0.0, -2.0])


def test_choose_weight_scale_covers_max():
    w = np.array([-0.7, 0.3, 0.5], np.float32)
    s = quant.choose_weight_scale(w)
    q = quant.quantize(w, s, 0)
    assert int(np.abs(q).max()) == 127  # max magnitude uses full range


def test_choose_act_qparams_relu_convention():
    x = np.array([0.0, 1.0, 2.0], np.float32)
    s, zp = quant.choose_act_qparams(x, relu=True)
    assert zp == -128 and s > 0


@settings(max_examples=50, deadline=None)
@given(st.integers(-(2**20), 2**20), st.floats(1e-6, 0.1, allow_nan=False),
       st.integers(-128, 127))
def test_requantize_matches_formula(acc, mult, zp):
    got = int(quant.requantize(np.array([acc], np.int32), mult, zp)[0])
    want = int(np.clip(np.round(np.float64(acc) * mult) + zp, -128, 127))
    assert got == want
