//! Table V reproduction: TVM schedules on microcontroller hardware.
//!
//! 4 models × {Default,ARM}×{NHWC,NCHW} × 4 targets × {untuned, tuned}
//! — inference seconds, with "—" for memory/tuning failures, plus the
//! paper-shape checks (NCHW beats NHWC on CNNs, catastrophic NHWC on
//! SPI-flash targets, ARM dense 2× on toycar, esp32 tuned column all
//! "—", vww failures on small targets).

mod common;

use common::{bench_env, load_or_exit, PAPER_MODELS};
use mlonmcu::backends::{self, BackendConfig};
use mlonmcu::schedules::Schedule;
use mlonmcu::targets::{self, table5_targets};
use mlonmcu::tuner;

const SCHEDULES: [&str; 4] =
    ["default-nhwc", "default-nchw", "arm-nhwc", "arm-nchw"];

/// Bench-time tuning budget (paper used >=600; shape converges long
/// before — ablation_tuning sweeps this axis).
const TRIALS: usize = 150;

fn main() {
    let env = bench_env();
    println!("== Table II: hardware targets ==");
    for t in table5_targets() {
        let spec = targets::by_name(t).unwrap();
        let s = spec.spec();
        println!(
            "  {:<8} {:<11} {:>4} MHz  flash {:>9}  ram {:>8}",
            t, s.isa.name, s.clock_mhz, s.flash_total, s.ram_total
        );
    }
    println!("\n== Table V: TVM schedules on hardware (seconds; — = failed) ==");
    println!(
        "{:<8} {:<14} {:>21} {:>21} {:>21} {:>21}",
        "model", "schedule", "esp32c3 (no/yes)", "stm32f4 (no/yes)",
        "stm32f7 (no/yes)", "esp32 (no/yes)"
    );
    // results[model][schedule][target] = (untuned, tuned)
    let mut results: Vec<(String, String, Vec<(Option<f64>, Option<f64>)>)> =
        Vec::new();
    let backend = backends::by_name("tvmaot").unwrap();
    for model in PAPER_MODELS {
        let graph = load_or_exit(&env, model);
        for sched in SCHEDULES {
            let schedule = Schedule::parse(sched).unwrap();
            let mut row = Vec::new();
            for tname in table5_targets() {
                let target = targets::by_name(tname).unwrap();
                let untuned = run_once(&*backend, &graph, &*target, schedule);
                let tuned = if target.supports_tuning() {
                    tuner::tune(
                        &*backend, &graph, &*target, schedule,
                        tuner::TuneOpts { trials: TRIALS, seed: 99 },
                    )
                    .ok()
                    .map(|t| t.best_seconds)
                } else {
                    None // esp32: MicroTVM cannot tune (paper "—")
                };
                row.push((untuned, tuned));
            }
            print_row(model, sched, &row);
            results.push((model.to_string(), sched.to_string(), row));
        }
    }

    // ---------------------------- paper-shape checks --------------------
    let cell = |m: &str, s: &str, t: usize| -> (Option<f64>, Option<f64>) {
        results
            .iter()
            .find(|(rm, rs, _)| rm == m && rs == s)
            .map(|(_, _, row)| row[t])
            .unwrap()
    };
    let mut failures = Vec::new();
    let mut check = |cond: bool, what: &str| {
        if !cond {
            failures.push(what.to_string());
        }
    };
    // esp32 tuned column entirely "—"
    check(
        results.iter().all(|(_, _, row)| row[3].1.is_none()),
        "esp32 tuned column all —",
    );
    // NCHW < NHWC untuned for CNNs on every target where both ran
    for m in ["aww", "vww", "resnet"] {
        for t in 0..4 {
            if let (Some(nhwc), Some(nchw)) =
                (cell(m, "default-nhwc", t).0, cell(m, "default-nchw", t).0)
            {
                check(nchw < nhwc, &format!("{m} NCHW<NHWC on target {t}"));
            }
        }
    }
    // catastrophic NHWC on SPI-flash targets for large-conv models
    // (paper: 26-62x; our analytic flash-thrash model reproduces the
    // blowup directionally at >4x — see EXPERIMENTS.md)
    for m in ["vww", "resnet"] {
        if let (Some(nhwc), Some(nchw)) =
            (cell(m, "default-nhwc", 0).0, cell(m, "default-nchw", 0).0)
        {
            check(
                nhwc / nchw > 4.0,
                &format!("{m} esp32c3 NHWC blowup >4x (got {:.1}x)", nhwc / nchw),
            );
        }
    }
    // ...but mild (<6x) on internal-flash stm32f7
    for m in ["vww", "resnet"] {
        if let (Some(nhwc), Some(nchw)) =
            (cell(m, "default-nhwc", 2).0, cell(m, "default-nchw", 2).0)
        {
            check(
                nhwc / nchw < 6.0,
                &format!("{m} stm32f7 NHWC mild (got {:.1}x)", nhwc / nchw),
            );
        }
    }
    // aww (small weight windows, all cache-resident): gap stays mild
    if let (Some(nhwc), Some(nchw)) =
        (cell("aww", "default-nhwc", 0).0, cell("aww", "default-nchw", 0).0)
    {
        check(
            (1.2..3.5).contains(&(nhwc / nchw)),
            &format!("aww esp32c3 NHWC mild x1.5-2 (got {:.2}x)", nhwc / nchw),
        );
    }
    // ARM dense ~2x better on toycar
    for t in 0..3 {
        if let (Some(def), Some(arm)) =
            (cell("toycar", "default-nhwc", t).0, cell("toycar", "arm-nhwc", t).0)
        {
            check(
                def / arm > 1.5,
                &format!("toycar ARM 2x on target {t} (got {:.2}x)", def / arm),
            );
        }
    }
    // vww must fail on esp32 (flash) for all schedules
    check(
        (0..1).all(|_| SCHEDULES.iter().all(|s| cell("vww", s, 3).0.is_none())),
        "vww fails on esp32",
    );
    // vww default-NHWC fails on stm32f4 (arena + im2col workspace),
    // while NCHW runs there (paper Table V: "—" vs 0.395 s)
    check(cell("vww", "default-nhwc", 1).0.is_none(), "vww NHWC fails on stm32f4");
    check(cell("vww", "default-nchw", 1).0.is_some(), "vww NCHW runs on stm32f4");
    // tuning never hurts; x86-nhwc conv-only rows see ~no gain
    for (m, s, row) in &results {
        for (unt, tun) in row {
            if let (Some(u), Some(t)) = (unt, tun) {
                check(
                    *t <= *u * 1.0001,
                    &format!("{m}/{s} tuned <= untuned"),
                );
            }
        }
    }
    if failures.is_empty() {
        println!("\nall Table V shape checks PASSED");
    } else {
        println!("\nshape check FAILURES:");
        for f in &failures {
            println!("  {f}");
        }
        std::process::exit(1);
    }
}

fn run_once(
    backend: &dyn backends::Backend,
    graph: &mlonmcu::graph::Graph,
    target: &dyn targets::Target,
    schedule: Schedule,
) -> Option<f64> {
    let mut cfg = BackendConfig::default();
    cfg.schedule = Some(schedule);
    let build = backend.build(graph, &cfg).ok()?;
    let dep = target.deploy(&build, backend.framework()).ok()?;
    let input = vec![0i8; graph.tensor(graph.inputs[0]).numel()];
    let out = target.run(&build, &dep, &input, false).ok()?;
    Some(out.invoke_seconds)
}

fn print_row(model: &str, sched: &str, row: &[(Option<f64>, Option<f64>)]) {
    let fmt = |v: Option<f64>| match v {
        Some(s) => format!("{s:.3}"),
        None => "—".to_string(),
    };
    print!("{model:<8} {sched:<14}");
    for (u, t) in row {
        print!(" {:>10}/{:<10}", fmt(*u), fmt(*t));
    }
    println!();
}
