//! Table III reproduction: benchmark-runtime summary.
//!
//! Re-runs the paper's two benchmark campaigns as sessions and reports
//! host wall time for the Load–Compile and Load–Run stage spans, plus
//! the *simulated device* time (flash + run), which is what dominated
//! the paper's 43-minute Load–Run column on real hardware.

mod common;

use common::{bench_env, PAPER_MODELS};
use mlonmcu::session::{RunMatrix, Session};

fn main() {
    let env = bench_env();

    // -- Benchmark III-B: 20 backend-comparison runs on etiss ----------
    let m_b = RunMatrix::new()
        .models(PAPER_MODELS)
        .backends(["tflmi", "tflmc", "tvmaot", "tvmaot+", "tvmrt"])
        .targets(["etiss"]);
    let s_b = Session::new(&env).expect("session");
    let rep_b = s_b.run_matrix(&m_b, 2).expect("III-B session");
    let t_b = *s_b.last_timing.lock().unwrap();

    // -- Benchmark III-C: schedule sweep on 4 hw targets (untuned;
    //    the tuned half goes through the Tune stage in table5) --------
    let m_c = RunMatrix::new()
        .models(PAPER_MODELS)
        .backends(["tvmaot"])
        .targets(["esp32c3", "stm32f4", "stm32f7", "esp32"])
        .schedules(["default-nhwc", "default-nchw", "arm-nhwc", "arm-nchw"]);
    let s_c = Session::new(&env).expect("session");
    let rep_c = s_c.run_matrix(&m_c, 2).expect("III-C session");
    let t_c = *s_c.last_timing.lock().unwrap();

    println!("== Table III: benchmark runtime summary ==");
    println!(
        "{:<10} {:>6} {:>18} {:>18} {:>20} {:>16} {:>14}",
        "benchmark", "#runs", "host load-compile", "host load-run",
        "simulated device", "cache hit/miss", "builds run"
    );
    for (name, t, paper_lc, paper_lr) in [
        ("III-B", t_b, 340.0, 350.0),
        ("III-C", t_c, 960.0, 2580.0),
    ] {
        println!(
            "{:<10} {:>6} {:>16.2} s {:>16.2} s {:>18.1} s {:>11}/{:<4} {:>14}   (paper: {} s / {} s)",
            name, t.runs, t.load_compile_s, t.load_run_s, t.sim_s,
            t.cache_hits, t.cache_misses, t.stage_execs.builds,
            paper_lc, paper_lr
        );
    }
    println!(
        "\nok rows: III-B {}/{}   III-C {}/{}",
        rep_b
            .rows
            .iter()
            .filter(|r| r["status"].render() == "ok")
            .count(),
        rep_b.len(),
        rep_c
            .rows
            .iter()
            .filter(|r| r["status"].render() == "ok")
            .count(),
        rep_c.len(),
    );

    // shape checks: (1) the simulated-device time dominates host time
    // for the hardware campaign (the paper's central Table III
    // observation); (2) all 20 III-B runs succeed on the ISS.
    assert_eq!(t_b.runs, 20, "III-B must be 20 runs");
    assert!(
        rep_b.rows.iter().all(|r| r["status"].render() == "ok"),
        "all III-B runs must succeed on etiss"
    );
    assert!(
        t_c.sim_s > 5.0 * t_c.load_run_s.max(0.001),
        "hardware campaign must be dominated by device time \
         (sim {:.1}s vs host {:.1}s)",
        t_c.sim_s,
        t_c.load_run_s
    );
    // (3) the stage scheduler deduplicates shared prefixes: III-C is
    // 4 models × 4 schedules over 4 targets, so 16 distinct untuned
    // builds serve all 64 runs
    assert_eq!(
        t_c.stage_execs.builds, 16,
        "III-C must build one artifact per (model, schedule) prefix"
    );
    assert!(
        t_c.cache_hits >= 48,
        "III-C target sweep must reuse builds across targets \
         ({} hits)",
        t_c.cache_hits
    );
    println!("\nTable III shape checks PASSED");
}
