//! Ablation A3: AutoTVM trial budget vs achieved latency (paper
//! §III-C: "at least 600 iterations"; "more improvements can likely be
//! achieved by increasing the number of tuning iterations").

mod common;

use common::{bench_env, load_or_exit};
use mlonmcu::backends;
use mlonmcu::schedules::Schedule;
use mlonmcu::targets;
use mlonmcu::tuner::{tune, TuneOpts};

fn main() {
    let env = bench_env();
    let graph = load_or_exit(&env, "aww");
    let backend = backends::by_name("tvmaot").unwrap();
    let target = targets::by_name("esp32c3").unwrap();
    let base = Schedule::parse("default-nchw").unwrap();
    println!("== Ablation: tuning trials (aww / default-nchw / esp32c3) ==");
    println!("{:>7} {:>12} {:>10}", "trials", "best (s)", "gain");
    let mut prev_best = f64::MAX;
    for trials in [0usize, 10, 50, 150, 600] {
        let r = tune(
            &*backend, &graph, &*target, base,
            TuneOpts { trials: trials.max(1), seed: 42 },
        )
        .expect("tune");
        let best = if trials == 0 { r.baseline_seconds } else { r.best_seconds };
        let gain = (1.0 - best / r.baseline_seconds) * 100.0;
        println!("{trials:>7} {best:>12.4} {gain:>9.1}%");
        assert!(
            best <= prev_best * 1.0001,
            "more trials must never do worse (monotone best-so-far)"
        );
        prev_best = best;
    }
    println!("\ntuning-budget monotonicity PASSED");
}
