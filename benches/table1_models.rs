//! Table I reproduction: the MLPerf-Tiny model inventory — use case,
//! quantized size (ours vs paper), parameters and MACs.

mod common;

use common::{bench_env, load_or_exit, vs_paper, PAPER_MODELS};

const PAPER_KB: [(&str, &str, f64); 4] = [
    ("aww", "Keyword Spotting", 58.3),
    ("vww", "Visual Wake Words", 325.0),
    ("resnet", "Image Classification", 96.2),
    ("toycar", "Anomaly Detection", 270.0),
];

fn main() {
    let env = bench_env();
    println!("== Table I: MLPerf Tiny benchmark models ==");
    println!(
        "{:<8} {:<22} {:>12} {:>12} {:>10} {:>10}",
        "name", "use case", "size (kB)", "paper (kB)", "params", "MACs (M)"
    );
    let mut sizes = Vec::new();
    for model in PAPER_MODELS {
        let g = load_or_exit(&env, model);
        let (_, usecase, paper) =
            PAPER_KB.iter().find(|(m, _, _)| *m == model).unwrap();
        let kb = g.weight_bytes() as f64 / 1e3;
        println!(
            "{:<8} {:<22} {:>12.1} {:>12.1} {:>10} {:>10.2}  ({})",
            model,
            usecase,
            kb,
            paper,
            g.param_count(),
            g.macs() as f64 / 1e6,
            vs_paper(kb, *paper)
        );
        sizes.push((model, kb));
    }
    // shape: size ordering matches the paper's (aww < resnet < toycar < vww)
    let kb = |m: &str| sizes.iter().find(|(n, _)| *n == m).unwrap().1;
    assert!(
        kb("aww") < kb("resnet") && kb("resnet") < kb("toycar")
            && kb("toycar") < kb("vww"),
        "Table I size ordering violated"
    );
    println!("\nTable I ordering check PASSED (aww < resnet < toycar < vww)");
}
