//! Serve-tier saturation bench: many clients hammering a serve
//! daemon with warm `OP_GET`s plus a full queue lifecycle, proving
//! the three throughput claims of the serve overhaul:
//!
//!   1. warm GETs are answered from the server's in-memory hot cache
//!      — zero `EnvStore` reads on the hot path;
//!   2. concurrent clients make wall-clock progress together (no lock
//!      convoy);
//!   3. completed queues are retired, so a long-lived daemon's queue
//!      map returns to baseline.
//!
//! Usage:
//!   cargo bench --bench serve_saturation            # self-hosted,
//!       strict: spawns its own server and asserts all three claims
//!       against server internals
//!   cargo bench --bench serve_saturation -- --json  # same + write
//!       BENCH_serve.json (the CI artifact)
//!   cargo bench --bench serve_saturation -- --connect HOST:PORT \
//!       [--clients N] [--iters N] [--json]         # relaxed smoke
//!       against a live daemon (CI runs this against the fleet
//!       server); asserts only client-visible behaviour

use std::sync::Arc;
use std::time::Instant;

use mlonmcu::data::Json;
use mlonmcu::graph::model::testutil::tiny_conv;
use mlonmcu::session::cache::{load_key, Artifact, CachedStage, StageKey};
use mlonmcu::session::persist;
use mlonmcu::session::store::EnvStore;
use mlonmcu::session::transport::{
    Claim, Client, RemoteConfig, ServeConfig, Server,
};

const ENTRIES: usize = 16;

struct Opts {
    connect: Option<String>,
    clients: usize,
    iters: usize,
    json: bool,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts =
        Opts { connect: None, clients: 8, iters: 200, json: false };
    let mut i = 0;
    while i < args.len() {
        let value = |i: usize| {
            args.get(i + 1).cloned().unwrap_or_else(|| {
                eprintln!("{} needs a value", args[i]);
                std::process::exit(2);
            })
        };
        match args[i].as_str() {
            "--json" => opts.json = true,
            "--connect" => {
                opts.connect = Some(value(i));
                i += 1;
            }
            "--clients" => {
                opts.clients = value(i).parse().unwrap_or(8).clamp(1, 64);
                i += 1;
            }
            "--iters" => {
                opts.iters = value(i).parse().unwrap_or(200).clamp(1, 100_000);
                i += 1;
            }
            other => {
                // `cargo bench` passes harness flags through; ignore
                let _ = other;
            }
        }
        i += 1;
    }
    opts
}

fn client_for(addr: &str) -> Client {
    Client::new(RemoteConfig {
        addr: addr.to_string(),
        timeout_ms: 5000,
        retries: 2,
        backoff_ms: 50,
        grace_ms: 500,
    })
}

/// Distinct keys unlikely to collide with fleet traffic when pointed
/// at a shared daemon.
fn bench_key(i: usize) -> StageKey {
    load_key(0x5e7e_b000 + i as u64)
}

fn stat(j: &Json, k: &str) -> i64 {
    j.get(k).and_then(Json::as_i64).unwrap_or(0)
}

/// Seed the store through the wire, hammer it warm from `clients`
/// threads, then run one small queue to completion and drain it.
/// Returns the collected numbers; strict assertions happen only in
/// self-hosted mode where server internals are visible.
fn run(addr: &str, opts: &Opts) -> Vec<(&'static str, Json)> {
    let bytes: Vec<Vec<u8>> = (0..ENTRIES)
        .map(|i| {
            persist::encode(
                bench_key(i),
                &Artifact::Graph(Arc::new(tiny_conv())),
            )
        })
        .collect();
    let seeder = client_for(addr);
    for (i, b) in bytes.iter().enumerate() {
        seeder.put(CachedStage::Load, bench_key(i), b).unwrap();
    }
    let stats_before = seeder.stats().unwrap();

    // warm hammer: every thread is its own client (a fleet), cycling
    // through the seeded keys; all must come back intact
    let start = Instant::now();
    let workers: Vec<_> = (0..opts.clients)
        .map(|t| {
            let addr = addr.to_string();
            let expect = bytes.clone();
            let iters = opts.iters;
            std::thread::spawn(move || {
                let client = client_for(&addr);
                for n in 0..iters {
                    let i = (t + n) % ENTRIES;
                    let got = client
                        .get(CachedStage::Load, bench_key(i))
                        .unwrap()
                        .expect("seeded entry must be present");
                    assert_eq!(got, expect[i], "warm GET returned wrong bytes");
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    let elapsed = start.elapsed();
    let total_gets = opts.clients * opts.iters;

    // queue lifecycle: push, claim with riding deps, done, drain —
    // the daemon's queue count must return to its pre-push baseline
    let queues_baseline = stat(&stats_before, "queues");
    let doc = Json::obj(vec![
        ("lease_ms", Json::Num(2000.0)),
        (
            "tasks",
            Json::Arr(
                (0..2)
                    .map(|i| {
                        Json::obj(vec![
                            ("id", Json::Num((i + 1) as f64)),
                            ("kind", Json::Str("load".into())),
                            ("key", Json::Str(bench_key(i).hex())),
                            ("deps", Json::Arr(vec![])),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let qid = seeder.qpush(&doc).unwrap();
    let mut riding_entries = 0usize;
    for _ in 0..2 {
        let (claim, entries) = seeder.claim_deps(qid).unwrap();
        let Claim::Task(c) = claim else { panic!("queue must have tasks") };
        riding_entries += entries.len();
        let id = c
            .get("task")
            .and_then(|t| t.get("id"))
            .and_then(Json::as_i64)
            .expect("claim carries the task id");
        seeder
            .done(qid, id as u64, &Json::obj(vec![("id", Json::Num(id as f64))]))
            .unwrap();
    }
    assert!(
        riding_entries >= 2,
        "claimed tasks should carry their cached artifacts"
    );
    let poll = seeder.poll(qid).unwrap();
    assert_eq!(stat(&poll, "total"), 2, "both tasks drained");
    let stats_after = seeder.stats().unwrap();
    assert_eq!(
        stat(&stats_after, "queues"),
        queues_baseline,
        "completed queue must be retired, not leaked"
    );

    let hits = stat(&stats_after, "mem_hits") - stat(&stats_before, "mem_hits");
    let reads =
        stat(&stats_after, "store_reads") - stat(&stats_before, "store_reads");
    let served = stat(&stats_after, "bytes_served");
    let gets_per_sec = total_gets as f64 / elapsed.as_secs_f64().max(1e-9);
    println!(
        "{} client(s) x {} warm GET(s): {:.1} ms total, {:.0} gets/s",
        opts.clients,
        opts.iters,
        elapsed.as_secs_f64() * 1e3,
        gets_per_sec
    );
    println!(
        "server: {hits} mem hit(s), {reads} store read(s) during the warm \
         phase, {served} bytes served; queue retired to baseline"
    );

    vec![
        ("clients", Json::Num(opts.clients as f64)),
        ("iters", Json::Num(opts.iters as f64)),
        ("entries", Json::Num(ENTRIES as f64)),
        ("total_gets", Json::Num(total_gets as f64)),
        ("elapsed_ms", Json::Num(elapsed.as_secs_f64() * 1e3)),
        ("gets_per_sec", Json::Num(gets_per_sec)),
        ("warm_mem_hits", Json::Num(hits as f64)),
        ("warm_store_reads", Json::Num(reads as f64)),
        ("riding_entries", Json::Num(riding_entries as f64)),
    ]
}

fn main() {
    let opts = parse_opts();
    println!("== serve_saturation: serve-tier throughput ==");

    let mut fields = if let Some(addr) = &opts.connect {
        // relaxed smoke against a live daemon: other traffic may be
        // touching the store, so only client-visible claims hold
        println!("connecting to live daemon at {addr}");
        let fields = run(addr, &opts);
        let hits = fields
            .iter()
            .find(|(k, _)| *k == "warm_mem_hits")
            .and_then(|(_, v)| v.as_i64())
            .unwrap_or(0);
        assert!(hits > 0, "warm GETs must hit the server mem cache");
        fields
    } else {
        // self-hosted strict mode: server internals are visible, so
        // the zero-store-reads claim is asserted exactly
        let dir = std::env::temp_dir().join("mlonmcu_bench_serve");
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(EnvStore::open(&dir, u64::MAX).unwrap());
        let server = Server::spawn_with(
            Arc::clone(&store),
            "127.0.0.1:0",
            ServeConfig { mem_bytes: 32 << 20, max_conns: 128, idle_ms: 0 },
        )
        .unwrap();
        let addr = server.addr.to_string();

        let reads_cold = store.read_ops();
        let fields = run(&addr, &opts);
        let warm_reads = store.read_ops() - reads_cold;
        assert_eq!(
            warm_reads, 0,
            "warm phase must be served entirely from server memory"
        );
        let hits = fields
            .iter()
            .find(|(k, _)| *k == "warm_mem_hits")
            .and_then(|(_, v)| v.as_i64())
            .unwrap_or(0);
        assert!(hits > 0, "warm GETs must hit the server mem cache");
        assert_eq!(server.queue_count(), 0, "no queue survives its drain");

        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
        fields
    };

    if opts.json {
        fields.insert(0, ("bench", Json::Str("serve_saturation".into())));
        fields.push((
            "mode",
            Json::Str(
                if opts.connect.is_some() { "connect" } else { "self_host" }
                    .into(),
            ),
        ));
        let doc = Json::obj(fields);
        std::fs::write("BENCH_serve.json", doc.to_string())
            .expect("write BENCH_serve.json");
        println!("wrote BENCH_serve.json");
    }
}
