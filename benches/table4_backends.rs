//! Table IV reproduction: backend comparison on the ETISS target.
//!
//! For each MLPerf-Tiny model × {tflmi, tflmc, tvmaot, tvmaot+, tvmrt}
//! prints #Instr (Setup), #Instr (Invoke), ROM, RAM — the exact rows
//! of the paper's Table IV — plus the paper-shape checks (who wins,
//! by what factor).

mod common;

use common::{bench_env, load_or_exit, PAPER_MODELS};
use mlonmcu::backends::{self, BackendConfig};
use mlonmcu::targets;

/// Paper Table IV values for shape comparison:
/// (model, backend) -> (setup ×10³, invoke ×10⁶, rom kB, ram kB).
const PAPER: &[(&str, &str, f64, f64, f64, f64)] = &[
    ("aww", "tflmi", 264.0, 153.144, 143.0, 37.0),
    ("aww", "tflmc", 62.0, 153.143, 107.0, 28.0),
    ("aww", "tvmaot", 0.0, 29.819, 126.0, 174.0),
    ("aww", "tvmaot+", 0.0, 30.671, 122.0, 125.0),
    ("aww", "tvmrt", 2988.0, 33.660, 164.0, 1056.0),
    ("vww", "tflmi", 1025.0, 432.031, 416.0, 337.0),
    ("vww", "tflmc", 274.0, 432.028, 342.0, 274.0),
    ("vww", "tvmaot", 0.0, 89.672, 579.0, 496.0),
    ("vww", "tvmaot+", 0.0, 87.460, 571.0, 495.0),
    ("vww", "tvmrt", 10688.0, 91.885, 655.0, 4229.0),
    ("resnet", "tflmi", 217.0, 687.462, 183.0, 69.0),
    ("resnet", "tflmc", 41.0, 687.45, 160.0, 58.0),
    ("resnet", "tvmaot", 0.0, 114.802, 228.0, 125.0),
    ("resnet", "tvmaot+", 0.0, 116.115, 224.0, 108.0),
    ("resnet", "tvmrt", 3970.0, 115.671, 274.0, 1055.0),
    ("toycar", "tflmi", 71.0, 3.001, 345.0, 21.0),
    ("toycar", "tflmc", 5.0, 2.996, 330.0, 7.0),
    ("toycar", "tvmaot", 0.0, 2.441, 594.0, 8.0),
    ("toycar", "tvmaot+", 0.0, 2.457, 592.0, 7.0),
    ("toycar", "tvmrt", 5014.0, 2.442, 631.0, 1057.0),
];

fn paper_row(model: &str, backend: &str) -> Option<&'static (&'static str, &'static str, f64, f64, f64, f64)> {
    PAPER.iter().find(|r| r.0 == model && r.1 == backend)
}

fn main() {
    let env = bench_env();
    let etiss = targets::by_name("etiss").unwrap();
    println!("== Table IV: backend comparisons (target: etiss RV32GC) ==");
    println!(
        "{:<8} {:<8} {:>14} {:>14} {:>10} {:>10}   {:>22}",
        "model", "backend", "setup(x10^3)", "invoke(x10^6)", "ROM kB", "RAM kB",
        "vs paper (invoke,rom)"
    );
    let mut shape_failures = Vec::new();
    for model in PAPER_MODELS {
        let graph = load_or_exit(&env, model);
        let mut per_backend = std::collections::BTreeMap::new();
        for bname in backends::all_backend_names() {
            let backend = backends::by_name(bname).unwrap();
            let build = backend.build(&graph, &BackendConfig::default()).unwrap();
            let dep = etiss.deploy(&build, backend.framework()).unwrap();
            let input = vec![0i8; graph.tensor(graph.inputs[0]).numel()];
            let out = etiss.run(&build, &dep, &input, false).unwrap();
            let setup_k = out.setup_instructions as f64 / 1e3;
            let invoke_m = out.invoke_instructions as f64 / 1e6;
            let rom_kb = build.metrics.rom_total() as f64 / 1e3;
            let ram_kb = build.metrics.ram_total() as f64 / 1e3;
            let vs = paper_row(model, bname)
                .map(|p| {
                    format!(
                        "{} / {}",
                        common::vs_paper(invoke_m, p.3),
                        common::vs_paper(rom_kb, p.4)
                    )
                })
                .unwrap_or_default();
            println!(
                "{:<8} {:<8} {:>14.0} {:>14.3} {:>10.0} {:>10.0}   {:>22}",
                model, bname, setup_k, invoke_m, rom_kb, ram_kb, vs
            );
            per_backend.insert(bname, (setup_k, invoke_m, rom_kb, ram_kb));
        }
        // -- paper-shape assertions per model ---------------------------
        let g = |b: &str| per_backend[b];
        let (s_i, i_i, rom_i, ram_i) = g("tflmi");
        let (s_c, i_c, rom_c, ram_c) = g("tflmc");
        let (s_a, i_a, _rom_a, ram_a) = g("tvmaot");
        let (_s_p, _i_p, _rom_p, ram_p) = g("tvmaot+");
        let (s_r, _i_r, _rom_r, ram_r) = g("tvmrt");
        let mut check = |cond: bool, what: &str| {
            if !cond {
                shape_failures.push(format!("{model}: {what}"));
            }
        };
        check((i_i - i_c).abs() / i_i < 0.01, "tflmi==tflmc invoke");
        check(rom_c < rom_i, "tflmc ROM < tflmi ROM");
        check(ram_c < ram_i, "tflmc RAM < tflmi RAM");
        check(s_c < 0.3 * s_i, "tflmc setup -70%+");
        check(s_a < 1.0, "tvmaot setup ~0");
        check(i_a < i_i, "tvm invoke < tflm invoke");
        check(s_r > 1000.0, "tvmrt setup > 1M instr");
        check(ram_r > 1000.0, "tvmrt RAM > 1MB");
        check(ram_p <= ram_a, "usmp RAM <= aot RAM");
        if model != "toycar" {
            check(i_i / i_a > 2.0, "tvm speedup > 2x on CNNs");
            check(ram_i < ram_a, "tflm RAM < tvm RAM on CNNs");
        }
    }
    if shape_failures.is_empty() {
        println!("\nall Table IV shape checks PASSED");
    } else {
        println!("\nshape check FAILURES:");
        for f in &shape_failures {
            println!("  {f}");
        }
        std::process::exit(1);
    }
}
