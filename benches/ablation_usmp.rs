//! Ablation A1: the Unified Static Memory Planner (tvmaot+ vs tvmaot)
//! RAM savings per model — the paper reports −9…−28 % for three of the
//! four models (§III-B).

mod common;

use common::{bench_env, load_or_exit, PAPER_MODELS};
use mlonmcu::backends::{by_name, BackendConfig};

fn main() {
    let env = bench_env();
    println!("== Ablation: USMP (tvmaot+ vs tvmaot) ==");
    println!(
        "{:<8} {:>12} {:>12} {:>8}   paper",
        "model", "aot RAM", "aot+ RAM", "delta"
    );
    let paper = [("aww", -28.3), ("vww", -0.2), ("resnet", -13.6), ("toycar", -8.9)];
    for model in PAPER_MODELS {
        let g = load_or_exit(&env, model);
        let cfg = BackendConfig::default();
        let aot = by_name("tvmaot").unwrap().build(&g, &cfg).unwrap();
        let plus = by_name("tvmaot+").unwrap().build(&g, &cfg).unwrap();
        let a = aot.metrics.ram_total() as f64;
        let p = plus.metrics.ram_total() as f64;
        let delta = (p / a - 1.0) * 100.0;
        let paper_d = paper.iter().find(|(m, _)| *m == model).unwrap().1;
        println!(
            "{:<8} {:>10.1}kB {:>10.1}kB {:>7.1}%   {paper_d:+.1}%",
            model,
            a / 1e3,
            p / 1e3,
            delta
        );
        assert!(p <= a, "{model}: USMP must never increase RAM");
    }
    println!("\nUSMP ablation check PASSED (tvmaot+ <= tvmaot everywhere)");
}
