//! Shared bench harness (criterion is not reachable offline): warmup +
//! timed iterations with mean/min/stddev, plus helpers shared by the
//! paper-table benches.

use std::time::Instant;

use mlonmcu::config::Environment;
use mlonmcu::frontends;
use mlonmcu::graph::Graph;

pub const PAPER_MODELS: [&str; 4] = ["aww", "vww", "resnet", "toycar"];

/// Measured statistics of a benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
}

impl BenchStats {
    pub fn fmt(&self) -> String {
        format!(
            "mean {:>10.4} ms  min {:>10.4} ms  sd {:>8.4} ms  (n={})",
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.stddev_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` with warmup; iteration count adapts to the workload.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().copied().fold(f64::MAX, f64::min);
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
        / samples.len() as f64;
    BenchStats {
        iters,
        mean_s: mean,
        min_s: min,
        stddev_s: var.sqrt(),
    }
}

/// Load a zoo model or exit with a friendly message.
pub fn load_or_exit(env: &Environment, name: &str) -> Graph {
    match frontends::load_model(name, &env.model_dirs()) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("cannot load model '{name}': {e}");
            eprintln!("run `make artifacts` before `cargo bench`");
            std::process::exit(0); // don't fail CI for missing artifacts
        }
    }
}

/// Environment rooted at the repo (artifacts/ beside Cargo.toml).
/// The persistent environment cache is disabled: benches measure cold
/// stage execution, and a warm store would (a) skew iteration timing
/// and (b) break repeat-run assertions on executed-stage counts.
pub fn bench_env() -> Environment {
    Environment::discover()
        .and_then(|e| e.with_overrides(&["cache.persist=false".into()]))
        .expect("environment")
}

/// Render a ratio vs the paper's value.
pub fn vs_paper(ours: f64, paper: f64) -> String {
    if paper == 0.0 {
        return "-".into();
    }
    format!("{:+.0}%", (ours / paper - 1.0) * 100.0)
}
