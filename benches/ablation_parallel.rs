//! Ablation A2: the parallel session executor (paper §II design
//! principle "Parallelism"). Sweeps the worker count over the III-B
//! campaign and reports wall-time scaling.

mod common;

use common::{bench, bench_env, PAPER_MODELS};
use mlonmcu::session::{RunMatrix, Session};

fn main() {
    let env = bench_env();
    let matrix = RunMatrix::new()
        .models(PAPER_MODELS)
        .backends(["tflmi", "tvmaot"])
        .targets(["etiss"]);
    println!("== Ablation: session parallelism (8-run campaign) ==");
    let mut base = None;
    for workers in [1usize, 2, 4, 8] {
        let stats = bench(0, 3, || {
            let s = Session::new(&env).expect("session");
            s.run_matrix(&matrix, workers).expect("matrix");
        });
        let speedup = base.map(|b: f64| b / stats.mean_s).unwrap_or(1.0);
        if base.is_none() {
            base = Some(stats.mean_s);
        }
        println!(
            "workers={workers:<2} {}  speedup x{speedup:.2}",
            stats.fmt()
        );
    }
    println!("\n(single-core host: speedups bounded by available CPUs; \
             the executor must at least not slow down)");
}
