//! Hot-path microbenchmarks for the perf pass (§Perf): the TinyIR
//! executor's conv/dense inner loops, the end-to-end single-run
//! latency per model, and the cost-only (tuner measure loop) path.
//! Records ns/MAC — the number the EXPERIMENTS.md §Perf log tracks.

mod common;

use common::{bench, bench_env, load_or_exit, PAPER_MODELS};
use mlonmcu::backends::{by_name, BackendConfig};
use mlonmcu::targets;

fn main() {
    let env = bench_env();
    let etiss = targets::by_name("etiss").unwrap();
    println!("== hotpath: executor performance (host) ==");
    println!(
        "{:<8} {:>10} {:>14} {:>12} {:>14}",
        "model", "MACs (M)", "full run", "ns/MAC", "cost-only"
    );
    for model in PAPER_MODELS {
        let graph = load_or_exit(&env, model);
        let build = by_name("tvmaot")
            .unwrap()
            .build(&graph, &BackendConfig::default())
            .unwrap();
        let dep = etiss.deploy(&build, "tvm").unwrap();
        let input = vec![1i8; graph.tensor(graph.inputs[0]).numel()];
        let macs = graph.macs() as f64;
        let iters = if macs > 5e6 { 3 } else { 10 };
        let full = bench(1, iters, || {
            etiss.run(&build, &dep, &input, true).unwrap();
        });
        let dry = bench(1, 50, || {
            etiss.run(&build, &dep, &input, false).unwrap();
        });
        println!(
            "{:<8} {:>10.2} {:>12.2}ms {:>12.2} {:>12.4}ms",
            model,
            macs / 1e6,
            full.min_s * 1e3,
            full.min_s * 1e9 / macs,
            dry.min_s * 1e3,
        );
    }
    println!("\n(cost-only is the tuner measure loop — must stay <1ms)");
}
