//! Hot-path microbenchmarks for the perf pass (§Perf): plan-compile
//! time, the steady-state (compile-once) invoke, ns/MAC, and the
//! cost-only path (the tuner measure loop — a cached-struct copy
//! since the ExecPlan refactor). Records the numbers the
//! benches/NOTES.md §Perf log tracks.
//!
//! Usage:
//!   cargo bench --bench hotpath                      # paper models
//!   cargo bench --bench hotpath -- --json m1 m2 ...  # quick mode:
//!       bench the named models and emit BENCH_hotpath.json (the CI
//!       perf-trajectory artifact). Explicitly named models must
//!       resolve; the run fails otherwise.

mod common;

use common::{bench, bench_env, load_or_exit, PAPER_MODELS};
use mlonmcu::backends::{by_name, BackendConfig};
use mlonmcu::data::Json;
use mlonmcu::frontends;
use mlonmcu::graph::Graph;
use mlonmcu::mcu::ExecPlan;
use mlonmcu::targets;

struct ModelRow {
    name: String,
    macs: f64,
    full_ms: f64,
    ns_per_mac: f64,
    cost_only_us: f64,
    plan_compile_ms: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_mode = args.iter().any(|a| a == "--json");
    let named: Vec<String> =
        args.iter().filter(|a| !a.starts_with("--")).cloned().collect();
    let models: Vec<String> = if named.is_empty() {
        PAPER_MODELS.iter().map(|s| s.to_string()).collect()
    } else {
        named.clone()
    };

    let env = bench_env();
    let etiss = targets::by_name("etiss").unwrap();
    println!("== hotpath: executor performance (host) ==");
    println!(
        "{:<10} {:>10} {:>12} {:>10} {:>12} {:>12}",
        "model", "MACs (M)", "full run", "ns/MAC", "cost-only", "plan-compile"
    );
    let mut rows: Vec<ModelRow> = Vec::new();
    for model in &models {
        let graph: Graph = if named.is_empty() {
            load_or_exit(&env, model)
        } else {
            // explicitly requested (CI quick mode): must resolve
            match frontends::load_model(model, &env.model_dirs()) {
                Ok(g) => g,
                Err(e) => {
                    eprintln!("cannot load requested model '{model}': {e}");
                    std::process::exit(1);
                }
            }
        };
        let build = by_name("tvmaot")
            .unwrap()
            .build(&graph, &BackendConfig::default())
            .unwrap();
        let dep = etiss.deploy(&build, "tvm").unwrap();
        let input = vec![1i8; graph.tensor(graph.inputs[0]).numel()];
        let macs = graph.macs() as f64;
        let iters = if json_mode {
            5
        } else if macs > 5e6 {
            3
        } else {
            10
        };
        let spec = etiss.spec();
        let compile = bench(1, if json_mode { 20 } else { 30 }, || {
            ExecPlan::compile(&build.program, spec).unwrap();
        });
        let full = bench(1, iters, || {
            etiss.run(&build, &dep, &input, true).unwrap();
        });
        // the tuner's measure loop: pre-summed stats, no call walk
        let dry = bench(1, if json_mode { 200 } else { 50 }, || {
            etiss.run(&build, &dep, &input, false).unwrap();
        });
        let row = ModelRow {
            name: model.clone(),
            macs,
            full_ms: full.min_s * 1e3,
            ns_per_mac: full.min_s * 1e9 / macs,
            cost_only_us: dry.min_s * 1e6,
            plan_compile_ms: compile.min_s * 1e3,
        };
        println!(
            "{:<10} {:>10.2} {:>10.2}ms {:>10.2} {:>10.3}us {:>10.4}ms",
            row.name,
            row.macs / 1e6,
            row.full_ms,
            row.ns_per_mac,
            row.cost_only_us,
            row.plan_compile_ms,
        );
        rows.push(row);
    }
    println!(
        "\n(cost-only is the tuner measure loop — a cached ExecStats copy; \
         full run reuses the deployment's compile-once ExecPlan)"
    );

    if json_mode {
        let entries: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("macs", Json::Num(r.macs)),
                    ("full_ms", Json::Num(r.full_ms)),
                    ("ns_per_mac", Json::Num(r.ns_per_mac)),
                    ("cost_only_us", Json::Num(r.cost_only_us)),
                    ("plan_compile_ms", Json::Num(r.plan_compile_ms)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::Str("hotpath".into())),
            ("models", Json::Arr(entries)),
        ]);
        std::fs::write("BENCH_hotpath.json", doc.to_string())
            .expect("write BENCH_hotpath.json");
        println!("wrote BENCH_hotpath.json ({} model(s))", rows.len());
    }
}
