//! Metrics-overhead benchmark (observability pass): the cost of the
//! process-global metrics registry on the runtime's hottest loop.
//!
//! Two numbers per model, registry disabled vs enabled, on the same
//! workload: one tuner-style measure trial — a burst of steady-state
//! dry-run invokes followed by a single recorded cost sample (that is
//! the finest granularity at which production code observes metrics;
//! stage/wire/store sites are all coarser). Plus the raw primitive
//! cost: ns per `observe` call in both registry states — the disabled
//! path must stay a single relaxed atomic load.
//!
//! Usage:
//!   cargo bench --bench metrics_overhead                  # paper models
//!   cargo bench --bench metrics_overhead -- --json m1 ..  # quick mode:
//!       bench the named models and emit BENCH_metrics.json (the CI
//!       overhead-trajectory artifact). Named models must resolve.

mod common;

use common::{bench, bench_env, load_or_exit, PAPER_MODELS};
use mlonmcu::backends::{by_name, BackendConfig};
use mlonmcu::data::Json;
use mlonmcu::frontends;
use mlonmcu::graph::Graph;
use mlonmcu::targets;
use mlonmcu::util::metrics;

/// Invokes per measured trial: the shape of one tuner measure step
/// (repeat the invoke, record one cost sample).
const INVOKES_PER_TRIAL: usize = 16;

struct ModelRow {
    name: String,
    off_us: f64,
    on_us: f64,
    overhead_pct: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_mode = args.iter().any(|a| a == "--json");
    let named: Vec<String> =
        args.iter().filter(|a| !a.starts_with("--")).cloned().collect();
    let models: Vec<String> = if named.is_empty() {
        PAPER_MODELS.iter().map(|s| s.to_string()).collect()
    } else {
        named.clone()
    };

    let env = bench_env();
    let etiss = targets::by_name("etiss").unwrap();

    // primitive cost first: ns per observe() with the registry off/on
    let per_loop = 10_000u32;
    metrics::disable();
    let prim_off = bench(2, 30, || {
        for i in 0..per_loop {
            metrics::observe("bench.primitive.us", i as u64);
        }
    });
    metrics::enable();
    let prim_on = bench(2, 30, || {
        for i in 0..per_loop {
            metrics::observe("bench.primitive.us", i as u64);
        }
    });
    metrics::disable();
    let _ = metrics::drain();
    let disabled_ns = prim_off.min_s * 1e9 / per_loop as f64;
    let enabled_ns = prim_on.min_s * 1e9 / per_loop as f64;
    println!("== metrics_overhead: registry cost ==");
    println!(
        "observe(): disabled {disabled_ns:.1} ns/op, \
         enabled {enabled_ns:.1} ns/op"
    );

    println!(
        "{:<10} {:>14} {:>14} {:>10}",
        "model", "trial off", "trial on", "overhead"
    );
    let mut rows: Vec<ModelRow> = Vec::new();
    for model in &models {
        let graph: Graph = if named.is_empty() {
            load_or_exit(&env, model)
        } else {
            match frontends::load_model(model, &env.model_dirs()) {
                Ok(g) => g,
                Err(e) => {
                    eprintln!("cannot load requested model '{model}': {e}");
                    std::process::exit(1);
                }
            }
        };
        let build = by_name("tvmaot")
            .unwrap()
            .build(&graph, &BackendConfig::default())
            .unwrap();
        let dep = etiss.deploy(&build, "tvm").unwrap();
        let input = vec![1i8; graph.tensor(graph.inputs[0]).numel()];
        let iters = if json_mode { 60 } else { 30 };
        let trial = || {
            let clock = metrics::clock();
            for _ in 0..INVOKES_PER_TRIAL {
                etiss.run(&build, &dep, &input, false).unwrap();
            }
            clock.observe("bench.trial.us");
        };
        metrics::disable();
        let off = bench(5, iters, trial);
        metrics::enable();
        let on = bench(5, iters, trial);
        metrics::disable();
        let _ = metrics::drain();
        let row = ModelRow {
            name: model.clone(),
            off_us: off.min_s * 1e6,
            on_us: on.min_s * 1e6,
            overhead_pct: (on.min_s / off.min_s - 1.0) * 100.0,
        };
        println!(
            "{:<10} {:>12.2}us {:>12.2}us {:>+9.2}%",
            row.name, row.off_us, row.on_us, row.overhead_pct
        );
        rows.push(row);
    }
    println!(
        "\n(trial = {INVOKES_PER_TRIAL} steady-state dry invokes + one \
         recorded cost sample — the tuner measure-loop shape; overhead \
         is min-vs-min, acceptance bound <2%)"
    );

    if json_mode {
        let entries: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("off_us", Json::Num(r.off_us)),
                    ("on_us", Json::Num(r.on_us)),
                    ("overhead_pct", Json::Num(r.overhead_pct)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::Str("metrics_overhead".into())),
            ("invokes_per_trial", Json::Num(INVOKES_PER_TRIAL as f64)),
            ("observe_disabled_ns", Json::Num(disabled_ns)),
            ("observe_enabled_ns", Json::Num(enabled_ns)),
            ("models", Json::Arr(entries)),
        ]);
        std::fs::write("BENCH_metrics.json", doc.to_string())
            .expect("write BENCH_metrics.json");
        println!("wrote BENCH_metrics.json ({} model(s))", rows.len());
    }
}
